//! Model definitions shared between the Rust request path and the python
//! build path.
//!
//! The three architectures are exactly the paper's (§III-B):
//!
//! * **MLP** — 784 → 200 (ReLU) → 10, cross-entropy (experiment 1).
//! * **CNN** — conv3×3(1→16) ReLU, conv3×3(16→32) ReLU, maxpool/2,
//!   FC 6272 → 10 (experiment 2).
//! * **VGG-like** — three conv blocks (3→32→64→128, each conv3×3 + ReLU +
//!   maxpool/2), FC 2048 → 10 on CIFAR-10 (experiment 3; the paper's
//!   dropout layers are omitted — see DESIGN.md §4).
//!
//! [`ModelSpec`] describes parameter names/shapes; the same layout is
//! produced by `python/compile/model.py` and recorded in
//! `artifacts/manifest.json`, so the PJRT and native backends are
//! interchangeable. [`native`] holds the pure-Rust reference
//! implementation (forward, backward, eval) used as the default backend
//! and as the test oracle for the HLO path.

pub mod native;

use crate::tensor::Tensor;
use crate::util::Rng;

/// Which of the paper's architectures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    /// 784-200-10 MLP (paper experiment 1).
    Mlp,
    /// conv16-conv32-pool-FC CNN on 28×28×1 (paper experiment 2).
    Cnn,
    /// VGG-like 32-64-128 CNN on 32×32×3 (paper experiment 3).
    Vgg,
}

impl ModelKind {
    /// Parse from CLI/config name.
    pub fn parse(s: &str) -> Option<ModelKind> {
        match s.to_ascii_lowercase().as_str() {
            "mlp" => Some(ModelKind::Mlp),
            "cnn" => Some(ModelKind::Cnn),
            "vgg" | "vgg-like" | "vgglike" => Some(ModelKind::Vgg),
            _ => None,
        }
    }

    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Mlp => "mlp",
            ModelKind::Cnn => "cnn",
            ModelKind::Vgg => "vgg",
        }
    }
}

/// One named parameter tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamSpec {
    /// e.g. `fc1.weight`
    pub name: String,
    /// row-major shape; 2-D = FC weight (SVD-compressed), 4-D = conv
    /// kernel (Tucker-compressed), 1-D = bias (quantize-only)
    pub shape: Vec<usize>,
}

impl ParamSpec {
    fn new(name: &str, shape: &[usize]) -> Self {
        ParamSpec { name: name.to_string(), shape: shape.to_vec() }
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    /// True when the parameter has no elements (never, in practice).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Full description of a model's parameter layout and input geometry.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// Architecture.
    pub kind: ModelKind,
    /// Input shape per sample, channels-first (e.g. `[1, 28, 28]`).
    pub input_shape: Vec<usize>,
    /// Number of classes (always 10 here).
    pub num_classes: usize,
    /// Parameters in a fixed traversal order shared with python.
    pub params: Vec<ParamSpec>,
}

impl ModelSpec {
    /// Build the spec for one of the paper's architectures.
    pub fn new(kind: ModelKind) -> Self {
        match kind {
            ModelKind::Mlp => ModelSpec {
                kind,
                input_shape: vec![784],
                num_classes: 10,
                params: vec![
                    ParamSpec::new("fc1.weight", &[200, 784]),
                    ParamSpec::new("fc1.bias", &[200]),
                    ParamSpec::new("fc2.weight", &[10, 200]),
                    ParamSpec::new("fc2.bias", &[10]),
                ],
            },
            ModelKind::Cnn => ModelSpec {
                kind,
                input_shape: vec![1, 28, 28],
                num_classes: 10,
                params: vec![
                    ParamSpec::new("conv1.weight", &[16, 1, 3, 3]),
                    ParamSpec::new("conv1.bias", &[16]),
                    ParamSpec::new("conv2.weight", &[32, 16, 3, 3]),
                    ParamSpec::new("conv2.bias", &[32]),
                    ParamSpec::new("fc.weight", &[10, 32 * 14 * 14]),
                    ParamSpec::new("fc.bias", &[10]),
                ],
            },
            ModelKind::Vgg => ModelSpec {
                kind,
                input_shape: vec![3, 32, 32],
                num_classes: 10,
                params: vec![
                    ParamSpec::new("conv1.weight", &[32, 3, 3, 3]),
                    ParamSpec::new("conv1.bias", &[32]),
                    ParamSpec::new("conv2.weight", &[64, 32, 3, 3]),
                    ParamSpec::new("conv2.bias", &[64]),
                    ParamSpec::new("conv3.weight", &[128, 64, 3, 3]),
                    ParamSpec::new("conv3.bias", &[128]),
                    ParamSpec::new("fc.weight", &[10, 128 * 4 * 4]),
                    ParamSpec::new("fc.bias", &[10]),
                ],
            },
        }
    }

    /// Parameter shapes in order (what the codecs are built from).
    pub fn shapes(&self) -> Vec<Vec<usize>> {
        self.params.iter().map(|p| p.shape.clone()).collect()
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.params.iter().map(|p| p.len()).sum()
    }

    /// Flat input dimension per sample.
    pub fn input_dim(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// He/Kaiming-style initialization, deterministic in `seed`.
    /// Matches `python/compile/model.py::init_params` (same scheme, not
    /// bit-identical — cross-backend tests compare behaviour, not bits).
    pub fn init_params(&self, seed: u64) -> Vec<Tensor> {
        let mut rng = Rng::new(seed);
        self.params
            .iter()
            .map(|p| {
                if p.shape.len() == 1 {
                    Tensor::zeros(&p.shape)
                } else {
                    // fan_in: product of all dims but the first
                    let fan_in: usize = p.shape[1..].iter().product();
                    let std = (2.0 / fan_in as f32).sqrt();
                    let mut t = Tensor::randn(&p.shape, &mut rng);
                    t.scale(std);
                    t
                }
            })
            .collect()
    }
}

/// Uniform interface over the native Rust backend and the PJRT/HLO
/// backend — what FL clients and the server evaluator call.
pub trait ModelOps: Send {
    /// The model's spec.
    fn spec(&self) -> &ModelSpec;

    /// Mean loss over the batch and gradients w.r.t. every parameter,
    /// in spec order. `x` is `[B, input_dim]` (flat), `y` are labels.
    fn loss_grad(&self, params: &[Tensor], x: &Tensor, y: &[u32]) -> (f32, Vec<Tensor>);

    /// Mean loss and number of correct predictions on a batch.
    fn eval(&self, params: &[Tensor], x: &Tensor, y: &[u32]) -> (f32, usize);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_match_paper_architectures() {
        let mlp = ModelSpec::new(ModelKind::Mlp);
        // 784*200 + 200 + 200*10 + 10 = 159,010 params
        assert_eq!(mlp.num_params(), 784 * 200 + 200 + 200 * 10 + 10);
        assert_eq!(mlp.input_dim(), 784);

        let cnn = ModelSpec::new(ModelKind::Cnn);
        assert_eq!(
            cnn.num_params(),
            16 * 9 + 16 + 32 * 16 * 9 + 32 + 10 * 6272 + 10
        );

        let vgg = ModelSpec::new(ModelKind::Vgg);
        assert_eq!(vgg.input_dim(), 3 * 32 * 32);
        assert_eq!(vgg.params.len(), 8);
    }

    #[test]
    fn init_deterministic_and_scaled() {
        let spec = ModelSpec::new(ModelKind::Mlp);
        let a = spec.init_params(7);
        let b = spec.init_params(7);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x, y);
        }
        // biases zero
        assert_eq!(a[1].fro_norm(), 0.0);
        // weight std approx sqrt(2/784)
        let w = &a[0];
        let std = (crate::tensor::sq_norm(w) / w.len() as f64).sqrt();
        let expect = (2.0 / 784.0f64).sqrt();
        assert!((std - expect).abs() / expect < 0.05, "std {std} vs {expect}");
    }

    #[test]
    fn kind_parse() {
        assert_eq!(ModelKind::parse("MLP"), Some(ModelKind::Mlp));
        assert_eq!(ModelKind::parse("vgg-like"), Some(ModelKind::Vgg));
        assert_eq!(ModelKind::parse("nope"), None);
    }
}
