//! Micro-benchmark harness (the offline substitute for `criterion` —
//! DESIGN.md §4): warmup, fixed-duration sampling, median + MAD, a
//! uniform report line, and — through [`suite`] — named suites with a
//! machine-readable JSON trajectory (`BENCH_*.json`) plus a baseline
//! diff that classifies every case as improved / regressed / unchanged
//! (DESIGN.md §5).
//!
//! [`suites`] holds the crate's two canonical suites (`kernels`,
//! `round`) and the `qrr bench` CLI entry; every `cargo bench` binary
//! routes through the same runners.

pub mod suite;
pub mod suites;

pub use suite::{CaseDiff, DeltaClass, Suite, SuiteReport};

use std::time::{Duration, Instant};

use crate::config::Json;

/// Result of one benchmark case.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchResult {
    /// case label
    pub name: String,
    /// number of timed iterations
    pub samples: usize,
    /// median per-iteration time
    pub median: Duration,
    /// median absolute deviation
    pub mad: Duration,
    /// optional throughput unit count per iteration (elements, bits, …)
    pub units_per_iter: Option<f64>,
    /// schema-stable numeric annotations, sorted by key (e.g. the round
    /// suite's `bits_up`/`bits_down`/`ratio` accounting); absent from
    /// the JSON when empty, so pre-existing baselines still parse
    pub extras: Vec<(String, f64)>,
}

impl BenchResult {
    /// One human-readable line: `name  median ± mad  (throughput)`.
    pub fn line(&self) -> String {
        let med = self.median.as_secs_f64();
        let mad = self.mad.as_secs_f64();
        let mut s = format!(
            "{:<44} {:>12} ± {:>10}  ({} samples)",
            self.name,
            fmt_time(med),
            fmt_time(mad),
            self.samples
        );
        if let Some(u) = self.units_per_iter {
            if med > 0.0 {
                s.push_str(&format!("  {:>12}/s", fmt_count(u / med)));
            }
        }
        s
    }

    /// Schema-stable JSON object: times as integer nanoseconds.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::Str(self.name.clone())),
            ("samples", Json::Num(self.samples as f64)),
            ("median_ns", Json::Num(self.median.as_nanos() as f64)),
            ("mad_ns", Json::Num(self.mad.as_nanos() as f64)),
        ];
        if let Some(u) = self.units_per_iter {
            pairs.push(("units_per_iter", Json::Num(u)));
        }
        let mut j = Json::obj(pairs);
        if !self.extras.is_empty() {
            if let Json::Obj(m) = &mut j {
                m.insert(
                    "extras".into(),
                    Json::Obj(
                        self.extras
                            .iter()
                            .map(|(k, v)| (k.clone(), Json::Num(*v)))
                            .collect(),
                    ),
                );
            }
        }
        j
    }

    /// Parse the object written by [`Self::to_json`].
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let field = |k: &str| {
            j.get(k)
                .ok_or_else(|| anyhow::anyhow!("bench case missing field {k:?}"))
        };
        Ok(BenchResult {
            name: field("name")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("bench case name must be a string"))?
                .to_string(),
            samples: field("samples")?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("bench case samples must be an integer"))?,
            median: Duration::from_nanos(
                field("median_ns")?
                    .as_u64()
                    .ok_or_else(|| anyhow::anyhow!("median_ns must be an integer"))?,
            ),
            mad: Duration::from_nanos(
                field("mad_ns")?
                    .as_u64()
                    .ok_or_else(|| anyhow::anyhow!("mad_ns must be an integer"))?,
            ),
            units_per_iter: j.get("units_per_iter").and_then(Json::as_f64),
            extras: match j.get("extras") {
                Some(Json::Obj(m)) => m
                    .iter()
                    .filter_map(|(k, v)| v.as_f64().map(|x| (k.clone(), x)))
                    .collect(),
                _ => Vec::new(),
            },
        })
    }
}

/// Median and median-absolute-deviation of a sample set (the harness'
/// robust statistics; MAD tolerates the occasional scheduler hiccup that
/// would wreck a mean ± stddev).
pub fn median_mad(samples: &[Duration]) -> (Duration, Duration) {
    assert!(!samples.is_empty(), "median of an empty sample set");
    let mut ts = samples.to_vec();
    ts.sort_unstable();
    let median = ts[ts.len() / 2];
    let mut devs: Vec<Duration> = ts
        .iter()
        .map(|&t| if t > median { t - median } else { median - t })
        .collect();
    devs.sort_unstable();
    let mad = devs[devs.len() / 2];
    (median, mad)
}

fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{secs:.3} s")
    }
}

fn fmt_count(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2}k", x / 1e3)
    } else {
        format!("{x:.1}")
    }
}

/// Benchmark runner with a time budget per case.
#[derive(Debug)]
pub struct Bench {
    /// warmup duration before sampling
    pub warmup: Duration,
    /// sampling budget
    pub budget: Duration,
    /// hard cap on samples
    pub max_samples: usize,
    /// true when running with the reduced CI settings
    pub fast: bool,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            max_samples: 200,
            fast: false,
        }
    }
}

impl Bench {
    /// Reduced settings for CI smoke runs (`--fast` / `QRR_BENCH_FAST=1`).
    pub fn fast() -> Self {
        Bench {
            warmup: Duration::from_millis(20),
            budget: Duration::from_millis(200),
            max_samples: 20,
            fast: true,
        }
    }

    /// [`Bench::fast`] when `QRR_BENCH_FAST` is set, else the default.
    pub fn from_env() -> Self {
        if crate::util::env::bench_fast() {
            Bench::fast()
        } else {
            Bench::default()
        }
    }

    /// Time `f` repeatedly; `units` (optional) is per-iteration work for
    /// throughput reporting. Prints and returns the result.
    pub fn run<T>(&self, name: &str, units: Option<f64>, mut f: impl FnMut() -> T) -> BenchResult {
        // warmup
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // sample
        let mut times = Vec::with_capacity(64);
        let s0 = Instant::now();
        while s0.elapsed() < self.budget && times.len() < self.max_samples {
            let t = Instant::now();
            std::hint::black_box(f());
            times.push(t.elapsed());
        }
        if times.is_empty() {
            let t = Instant::now();
            std::hint::black_box(f());
            times.push(t.elapsed());
        }
        let (median, mad) = median_mad(&times);
        let result = BenchResult {
            name: name.to_string(),
            samples: times.len(),
            median,
            mad,
            units_per_iter: units,
            extras: Vec::new(),
        };
        println!("{}", result.line());
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bench {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(20),
            max_samples: 50,
            ..Bench::default()
        };
        let r = b.run("spin", Some(1000.0), || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(r.median > Duration::ZERO);
        assert!(r.samples > 0);
        assert!(r.line().contains("spin"));
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2e-9).contains("ns"));
        assert!(fmt_time(2e-6).contains("µs"));
        assert!(fmt_time(2e-3).contains("ms"));
        assert!(fmt_time(2.0).contains("s"));
        assert_eq!(fmt_count(2_500_000.0), "2.50M");
    }

    #[test]
    fn median_mad_on_known_samples() {
        let ms = Duration::from_millis;
        // odd count: exact middle
        let (med, mad) = median_mad(&[ms(1), ms(9), ms(5), ms(3), ms(7)]);
        assert_eq!(med, ms(5));
        // devs |1-5|,|3-5|,|5-5|,|7-5|,|9-5| -> sorted 0,2,2,4,4
        assert_eq!(mad, ms(2));
        // even count: this harness takes the upper middle
        let (med, mad) = median_mad(&[ms(2), ms(4), ms(6), ms(8)]);
        assert_eq!(med, ms(6));
        // devs 4,2,0,2 -> sorted 0,2,2,4 -> upper middle 2
        assert_eq!(mad, ms(2));
        // constant samples: zero spread
        let (med, mad) = median_mad(&[ms(3), ms(3), ms(3)]);
        assert_eq!(med, ms(3));
        assert_eq!(mad, Duration::ZERO);
        // a single outlier must not move the median
        let (med, _) = median_mad(&[ms(5), ms(5), ms(5), ms(5), ms(500)]);
        assert_eq!(med, ms(5));
    }

    #[test]
    fn bench_result_json_roundtrip() {
        let r = BenchResult {
            name: "gemm/fc1_fwd_512x784x200".into(),
            samples: 42,
            median: Duration::from_nanos(1_234_567),
            mad: Duration::from_nanos(8_910),
            units_per_iter: Some(160_563_200.0),
            extras: vec![("bits_down".into(), 12_345.0), ("bits_up".into(), 67_890.0)],
        };
        let back = BenchResult::from_json(&r.to_json()).unwrap();
        assert_eq!(back, r);
        // and without throughput units
        let r2 = BenchResult { units_per_iter: None, ..r };
        let back2 = BenchResult::from_json(&r2.to_json()).unwrap();
        assert_eq!(back2, r2);
    }

    #[test]
    fn bench_result_json_rejects_malformed() {
        let j = Json::parse(r#"{"name":"x","samples":3}"#).unwrap();
        assert!(BenchResult::from_json(&j).is_err());
        let j = Json::parse(r#"{"name":4,"samples":3,"median_ns":1,"mad_ns":0}"#).unwrap();
        assert!(BenchResult::from_json(&j).is_err());
    }
}
