//! Named benchmark suites with a JSON trajectory and baseline diffing.
//!
//! A [`Suite`] runs cases through the shared [`Bench`] sampler and
//! collects their [`BenchResult`]s; [`Suite::finish`] yields a
//! [`SuiteReport`] that serializes to the schema-stable `BENCH_*.json`
//! shape (`qrr-bench/1`). [`SuiteReport::diff`] compares a run against a
//! committed baseline and classifies every case — the CI perf gate fails
//! on [`DeltaClass::Regressed`] entries (DESIGN.md §5).

use std::time::Duration;

use crate::config::Json;

use super::{Bench, BenchResult};

/// Schema tag written into every report.
pub const SCHEMA: &str = "qrr-bench/1";

/// A running suite: a name, a sampler, and the results so far.
#[derive(Debug)]
pub struct Suite {
    name: String,
    bench: Bench,
    results: Vec<BenchResult>,
    filter: Option<String>,
    last_skipped: bool,
}

impl Suite {
    /// New suite named `name` sampling with `bench`.
    pub fn new(name: impl Into<String>, bench: Bench) -> Self {
        Suite { name: name.into(), bench, results: Vec::new(), filter: None, last_skipped: false }
    }

    /// Restrict the suite to cases whose name contains `needle`
    /// (plain substring match; `None` clears the filter). Filtered-out
    /// cases are skipped entirely — not run, not recorded — and a
    /// following [`Suite::annotate_last`] becomes a no-op instead of
    /// annotating whatever case came before. `qrr bench --only SUBSTR`
    /// plugs in here.
    pub fn set_filter(&mut self, needle: Option<String>) {
        self.filter = needle;
    }

    /// Whether `name` passes the active case filter. Case registries
    /// check this before paying for expensive fixtures (sessions,
    /// pre-encoded cohorts) whose case would be skipped anyway.
    pub fn enabled(&self, name: &str) -> bool {
        match self.filter.as_deref() {
            Some(needle) => name.contains(needle),
            None => true,
        }
    }

    /// The underlying sampler.
    pub fn bench(&self) -> &Bench {
        &self.bench
    }

    /// Whether the suite runs with the reduced CI settings.
    pub fn is_fast(&self) -> bool {
        self.bench.fast
    }

    /// Run one repeatedly-sampled case; prints the line, records and
    /// returns the result. A case filtered out by [`Suite::set_filter`]
    /// never runs its closure: a zero-sample placeholder is returned
    /// and nothing is recorded.
    pub fn case<T>(
        &mut self,
        name: &str,
        units: Option<f64>,
        f: impl FnMut() -> T,
    ) -> BenchResult {
        if !self.enabled(name) {
            self.last_skipped = true;
            println!("{name:<44} skipped (--only filter)");
            return BenchResult {
                name: name.to_string(),
                samples: 0,
                median: Duration::ZERO,
                mad: Duration::ZERO,
                units_per_iter: None,
                extras: Vec::new(),
            };
        }
        self.last_skipped = false;
        let r = self.bench.run(name, units, f);
        self.results.push(r.clone());
        r
    }

    /// Run one single-shot case (for expensive end-to-end runs a sampler
    /// would repeat for seconds); records a one-sample result with zero
    /// MAD and returns the closure's value alongside it. Single-shot
    /// cases ignore the case filter — the caller needs the closure's
    /// value either way.
    pub fn once<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> (T, BenchResult) {
        let t = std::time::Instant::now();
        let value = f();
        let elapsed = t.elapsed();
        let r = BenchResult {
            name: name.to_string(),
            samples: 1,
            median: elapsed,
            mad: Duration::ZERO,
            units_per_iter: None,
            extras: Vec::new(),
        };
        println!("{}", r.line());
        self.last_skipped = false;
        self.results.push(r.clone());
        (value, r)
    }

    /// Attach schema-stable numeric annotations to the most recently
    /// recorded case (stored sorted by key; emitted as the case's
    /// `extras` object). The round suite uses this to record the
    /// uplink/downlink bit accounting next to its timings. A no-op when
    /// the most recent [`Suite::case`] call was skipped by the filter —
    /// the annotations belong to the skipped case, not whichever one
    /// happened to be recorded before it.
    pub fn annotate_last(&mut self, mut extras: Vec<(String, f64)>) {
        if self.last_skipped {
            return;
        }
        if let Some(last) = self.results.last_mut() {
            extras.sort_by(|a, b| a.0.cmp(&b.0));
            last.extras = extras;
        }
    }

    /// Seal the suite into its report, stamping the execution
    /// environment (threads, SIMD dispatch level, detected CPU
    /// features) so committed baselines say what machine and dispatch
    /// produced them.
    pub fn finish(self) -> SuiteReport {
        SuiteReport {
            suite: self.name,
            mode: if self.bench.fast { "fast".into() } else { "full".into() },
            threads: crate::exec::default_threads(),
            simd: crate::exec::simd::level().label().to_string(),
            cpu: crate::exec::simd::cpu_features().to_string(),
            estimated: false,
            cases: self.results,
        }
    }
}

/// The machine-readable outcome of one suite run (`BENCH_<suite>.json`).
#[derive(Debug, Clone, PartialEq)]
pub struct SuiteReport {
    /// suite name (`kernels`, `round`, …)
    pub suite: String,
    /// `"fast"` (CI smoke) or `"full"`
    pub mode: String,
    /// worker threads in effect during the run
    pub threads: usize,
    /// effective SIMD dispatch level during the run (`scalar`/`avx2`;
    /// `"unknown"` for baselines predating the field)
    pub simd: String,
    /// CPU vector features detected on the producing machine,
    /// independent of any `QRR_SIMD` override
    pub cpu: String,
    /// true when the numbers are hand-estimated placeholders rather
    /// than a measured run — `--check` reports against these without
    /// failing the gate
    pub estimated: bool,
    /// per-case results in execution order
    pub cases: Vec<BenchResult>,
}

impl SuiteReport {
    /// Serialize to the `qrr-bench/1` JSON shape.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::Str(SCHEMA.into())),
            ("suite", Json::Str(self.suite.clone())),
            ("mode", Json::Str(self.mode.clone())),
            ("threads", Json::Num(self.threads as f64)),
            ("simd", Json::Str(self.simd.clone())),
            ("cpu", Json::Str(self.cpu.clone())),
            ("estimated", Json::Bool(self.estimated)),
            (
                "cases",
                Json::Arr(self.cases.iter().map(BenchResult::to_json).collect()),
            ),
        ])
    }

    /// Parse a report; rejects unknown schema tags.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let schema = j
            .get("schema")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("bench report missing schema tag"))?;
        if schema != SCHEMA {
            anyhow::bail!("unsupported bench schema {schema:?} (want {SCHEMA:?})");
        }
        let str_field = |k: &str| -> anyhow::Result<String> {
            Ok(j.get(k)
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("bench report missing field {k:?}"))?
                .to_string())
        };
        let cases = j
            .get("cases")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("bench report missing cases array"))?
            .iter()
            .map(BenchResult::from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        // environment stamps default for baselines predating them
        let opt_str = |k: &str, default: &str| -> String {
            j.get(k).and_then(Json::as_str).unwrap_or(default).to_string()
        };
        Ok(SuiteReport {
            suite: str_field("suite")?,
            mode: str_field("mode")?,
            threads: j.get("threads").and_then(Json::as_usize).unwrap_or(0),
            simd: opt_str("simd", "unknown"),
            cpu: opt_str("cpu", "unknown"),
            estimated: j.get("estimated").and_then(Json::as_bool).unwrap_or(false),
            cases,
        })
    }

    /// Write the report to `path` (one JSON document).
    pub fn save(&self, path: &str) -> anyhow::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))
    }

    /// Load a report from `path`.
    pub fn load(path: &str) -> anyhow::Result<Self> {
        let text =
            std::fs::read_to_string(path).map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        Self::from_json(&Json::parse(text.trim()).map_err(|e| anyhow::anyhow!("{path}: {e}"))?)
    }

    /// Compare this run against `baseline`. `threshold` is the relative
    /// slowdown/speedup (e.g. `0.25` = 25%) beyond which a case counts
    /// as regressed/improved. Cases appear in this run's order; baseline
    /// cases this run no longer has are appended as
    /// [`DeltaClass::Removed`].
    pub fn diff(&self, baseline: &SuiteReport, threshold: f64) -> Vec<CaseDiff> {
        let mut out = Vec::with_capacity(self.cases.len());
        for cur in &self.cases {
            let base = baseline.cases.iter().find(|b| b.name == cur.name);
            out.push(match base {
                None => CaseDiff {
                    name: cur.name.clone(),
                    class: DeltaClass::New,
                    base_ns: None,
                    cur_ns: Some(cur.median.as_nanos() as u64),
                },
                Some(b) => CaseDiff {
                    name: cur.name.clone(),
                    class: classify(
                        cur.median.as_nanos() as u64,
                        b.median.as_nanos() as u64,
                        threshold,
                    ),
                    base_ns: Some(b.median.as_nanos() as u64),
                    cur_ns: Some(cur.median.as_nanos() as u64),
                },
            });
        }
        for b in &baseline.cases {
            if !self.cases.iter().any(|c| c.name == b.name) {
                out.push(CaseDiff {
                    name: b.name.clone(),
                    class: DeltaClass::Removed,
                    base_ns: Some(b.median.as_nanos() as u64),
                    cur_ns: None,
                });
            }
        }
        out
    }
}

/// How one case moved relative to the baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeltaClass {
    /// faster than baseline by more than the threshold
    Improved,
    /// slower than baseline by more than the threshold — the perf gate
    /// fails on these
    Regressed,
    /// within the threshold band
    Unchanged,
    /// case has no baseline entry (informational)
    New,
    /// baseline case missing from the current run (informational)
    Removed,
}

impl DeltaClass {
    /// Short lower-case label.
    pub fn label(&self) -> &'static str {
        match self {
            DeltaClass::Improved => "improved",
            DeltaClass::Regressed => "REGRESSED",
            DeltaClass::Unchanged => "unchanged",
            DeltaClass::New => "new",
            DeltaClass::Removed => "removed",
        }
    }
}

/// Classify `cur` vs `base` medians (nanoseconds) at `threshold`.
pub fn classify(cur_ns: u64, base_ns: u64, threshold: f64) -> DeltaClass {
    if base_ns == 0 {
        return if cur_ns == 0 { DeltaClass::Unchanged } else { DeltaClass::New };
    }
    let ratio = cur_ns as f64 / base_ns as f64;
    if ratio > 1.0 + threshold {
        DeltaClass::Regressed
    } else if ratio < 1.0 - threshold {
        DeltaClass::Improved
    } else {
        DeltaClass::Unchanged
    }
}

/// One case's movement vs the baseline.
#[derive(Debug, Clone, PartialEq)]
pub struct CaseDiff {
    /// case label
    pub name: String,
    /// classification at the diff's threshold
    pub class: DeltaClass,
    /// baseline median, ns (None for [`DeltaClass::New`])
    pub base_ns: Option<u64>,
    /// current median, ns (None for [`DeltaClass::Removed`])
    pub cur_ns: Option<u64>,
}

impl CaseDiff {
    /// Relative change `cur/base - 1` when both sides exist.
    pub fn rel_change(&self) -> Option<f64> {
        match (self.base_ns, self.cur_ns) {
            (Some(b), Some(c)) if b > 0 => Some(c as f64 / b as f64 - 1.0),
            _ => None,
        }
    }

    /// One aligned report line.
    pub fn line(&self) -> String {
        let ns = |v: Option<u64>| match v {
            Some(n) => super::fmt_time(n as f64 / 1e9),
            None => "-".into(),
        };
        let pct = match self.rel_change() {
            Some(d) => format!("{:+6.1}%", 100.0 * d),
            None => "      -".into(),
        };
        format!(
            "{:<44} {:>12} -> {:>12}  {pct}  {}",
            self.name,
            ns(self.base_ns),
            ns(self.cur_ns),
            self.class.label()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn case(name: &str, ns: u64) -> BenchResult {
        BenchResult {
            name: name.into(),
            samples: 10,
            median: Duration::from_nanos(ns),
            mad: Duration::ZERO,
            units_per_iter: None,
            extras: Vec::new(),
        }
    }

    fn report(cases: Vec<BenchResult>) -> SuiteReport {
        SuiteReport {
            suite: "t".into(),
            mode: "fast".into(),
            threads: 4,
            simd: "scalar".into(),
            cpu: "avx2,fma".into(),
            estimated: false,
            cases,
        }
    }

    #[test]
    fn suite_collects_cases_into_report() {
        let mut s = Suite::new(
            "demo",
            Bench {
                warmup: Duration::from_millis(1),
                budget: Duration::from_millis(5),
                max_samples: 5,
                ..Bench::default()
            },
        );
        s.case("a", None, || std::hint::black_box(1 + 1));
        let (v, r) = s.once("b", || 42);
        assert_eq!(v, 42);
        assert_eq!(r.samples, 1);
        let rep = s.finish();
        assert_eq!(rep.suite, "demo");
        assert_eq!(rep.cases.len(), 2);
        assert_eq!(rep.cases[0].name, "a");
        assert_eq!(rep.cases[1].name, "b");
        // the report is stamped with the run's execution environment
        assert_eq!(rep.simd, crate::exec::simd::level().label());
        assert_eq!(rep.cpu, crate::exec::simd::cpu_features());
        assert!(!rep.estimated);
    }

    #[test]
    fn filter_skips_cases_and_guards_annotate_last() {
        let mut s = Suite::new(
            "demo",
            Bench {
                warmup: Duration::from_millis(1),
                budget: Duration::from_millis(5),
                max_samples: 5,
                ..Bench::default()
            },
        );
        s.set_filter(Some("keep".into()));
        assert!(s.enabled("round/keep_this"));
        assert!(!s.enabled("round/other"));
        let kept = s.case("a_keep", None, || std::hint::black_box(1 + 1));
        assert!(kept.samples >= 1);
        s.annotate_last(vec![("k".into(), 1.0)]);
        // the skipped case's closure must never run
        let skipped = s.case("b_other", None, || -> u32 { panic!("filtered case ran") });
        assert_eq!(skipped.samples, 0);
        // annotating after a skip must not touch the recorded case
        s.annotate_last(vec![("wrong".into(), 2.0)]);
        let rep = s.finish();
        assert_eq!(rep.cases.len(), 1);
        assert_eq!(rep.cases[0].name, "a_keep");
        assert_eq!(rep.cases[0].extras, vec![("k".to_string(), 1.0)]);
    }

    #[test]
    fn legacy_reports_default_environment_stamps() {
        // baselines committed before the simd/cpu/estimated fields must
        // still parse, with explicit "unknown"/false defaults
        let j = Json::parse(
            r#"{"schema":"qrr-bench/1","suite":"kernels","mode":"fast","threads":4,"cases":[]}"#,
        )
        .unwrap();
        let rep = SuiteReport::from_json(&j).unwrap();
        assert_eq!(rep.simd, "unknown");
        assert_eq!(rep.cpu, "unknown");
        assert!(!rep.estimated);
        // and an estimated marker round-trips
        let mut rep2 = report(vec![]);
        rep2.estimated = true;
        let back = SuiteReport::from_json(&rep2.to_json()).unwrap();
        assert!(back.estimated);
        assert_eq!(back, rep2);
    }

    #[test]
    fn report_json_roundtrip_and_schema_check() {
        let rep = report(vec![case("x", 1000), case("y", 2000)]);
        let back = SuiteReport::from_json(&rep.to_json()).unwrap();
        assert_eq!(back, rep);
        // wrong schema tag is rejected
        let mut j = rep.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("schema".into(), Json::Str("qrr-bench/999".into()));
        }
        assert!(SuiteReport::from_json(&j).is_err());
    }

    #[test]
    fn report_save_load_roundtrip() {
        let rep = report(vec![case("k", 12_345)]);
        let path = std::env::temp_dir().join("qrr_bench_suite_test.json");
        let path = path.to_str().unwrap().to_string();
        rep.save(&path).unwrap();
        let back = SuiteReport::load(&path).unwrap();
        assert_eq!(back, rep);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn diff_classifies_improved_regressed_unchanged() {
        let base = report(vec![
            case("same", 1000),
            case("slower", 1000),
            case("faster", 1000),
            case("gone", 1000),
        ]);
        let cur = report(vec![
            case("same", 1100),   // +10% at 25% threshold -> unchanged
            case("slower", 1300), // +30% -> regressed
            case("faster", 600),  // -40% -> improved
            case("fresh", 500),   // no baseline -> new
        ]);
        let diffs = cur.diff(&base, 0.25);
        let class_of = |n: &str| diffs.iter().find(|d| d.name == n).unwrap().class;
        assert_eq!(class_of("same"), DeltaClass::Unchanged);
        assert_eq!(class_of("slower"), DeltaClass::Regressed);
        assert_eq!(class_of("faster"), DeltaClass::Improved);
        assert_eq!(class_of("fresh"), DeltaClass::New);
        assert_eq!(class_of("gone"), DeltaClass::Removed);
        assert_eq!(diffs.len(), 5);
    }

    #[test]
    fn classify_boundaries_and_degenerate_baselines() {
        assert_eq!(classify(1250, 1000, 0.25), DeltaClass::Unchanged); // exactly +25%
        assert_eq!(classify(1251, 1000, 0.25), DeltaClass::Regressed);
        assert_eq!(classify(750, 1000, 0.25), DeltaClass::Unchanged); // exactly -25%
        assert_eq!(classify(749, 1000, 0.25), DeltaClass::Improved);
        assert_eq!(classify(0, 0, 0.25), DeltaClass::Unchanged);
        assert_eq!(classify(10, 0, 0.25), DeltaClass::New);
    }

    #[test]
    fn diff_line_renders_percentages() {
        let base = report(vec![case("a", 1_000_000)]);
        let cur = report(vec![case("a", 2_000_000)]);
        let d = &cur.diff(&base, 0.25)[0];
        let line = d.line();
        assert!(line.contains("REGRESSED"), "{line}");
        assert!(line.contains("+100.0%"), "{line}");
    }
}
