//! The crate's canonical benchmark suites and the `qrr bench` CLI.
//!
//! Two suites cover the request path end to end (DESIGN.md §5):
//!
//! * `kernels` — every hot-path primitive: GEMM/matvec variants, thin
//!   QR, randomized SVD, Tucker, the LAQ quantizer + bit packing, wire
//!   encode/decode across all four entry kinds, and the full QRR
//!   client-encode / server-decode (serial and pool-fanned).
//! * `round` — full [`FlSession`](crate::fl::session::FlSession) rounds
//!   per scheme × participation over `InProcTransport`, i.e. the exact
//!   loop the experiments spend their time in.
//!
//! `qrr bench` writes `BENCH_kernels.json` / `BENCH_round.json` at the
//! repo root and, with `--check`, diffs the run against the committed
//! baselines and fails on any case regressing past the threshold — the
//! CI perf gate. The `cargo bench` binaries under `rust/benches/` are
//! thin wrappers over the same case registries, so both entry points
//! share one code path.

use anyhow::Result;

use crate::cli::Args;
use crate::compress::{compress_svd, compress_tucker, tucker_ranks};
use crate::config::{ExperimentConfig, PPolicy, ParticipationConfig, SchemeConfig};
use crate::fl::metrics::{markdown_table, TableRow};
use crate::fl::scheme::{make_server_scheme, SchemeKind};
use crate::fl::session::FlSessionBuilder;
use crate::fl::ShardedAggregator;
use crate::linalg::{
    gemm_acc, matmul, matmul_nt, matmul_tn, matvec, qr_thin, qr_thin_unblocked, svd_truncated,
    SvdMethod,
};
use crate::model::{native::NativeModel, ModelKind, ModelOps, ModelSpec};
use crate::net::{ClientUpdate, Decoder, Encoder};
use crate::qrr::{ClientCodec, QrrConfig, ServerCodec};
use crate::quant::{dequantize, pack_codes, quantize, unpack_codes};
use crate::slaq::SlaqMsg;
use crate::tensor::Tensor;
use crate::util::Rng;

use super::suite::{DeltaClass, Suite, SuiteReport};
use super::Bench;

/// Default perf-gate threshold: a case regressing by more than this
/// fraction vs the committed baseline fails `qrr bench --check`.
pub const DEFAULT_THRESHOLD: f64 = 0.25;

// ------------------------------------------------------------- kernels

/// Register the SVD-engine cases shared by the `kernels` suite and the
/// `fig1_spectrum` bench (same gradient-shaped 200×784 matrix).
pub fn svd_engine_cases(suite: &mut Suite) {
    let mut rng = Rng::new(7);
    let g = Tensor::randn(&[200, 784], &mut rng);
    for k in [20usize, 60] {
        suite.case(&format!("svd/randomized_k{k}_200x784"), None, || {
            svd_truncated(
                &g,
                k,
                SvdMethod::Randomized { oversample: 8, power_iters: 2, seed: 1 },
            )
        });
    }
    suite.case("svd/compress_p0.3_200x784", None, || {
        compress_svd(&g, 60, SvdMethod::Auto)
    });
}

/// Register every `kernels` case: the micro-benchmarks of each hot-path
/// primitive at the model's real shapes.
pub fn kernel_cases(suite: &mut Suite) {
    let mut rng = Rng::new(7);

    // GEMM at the MLP's shapes, plus the transpose-variant kernels
    for &(m, k, n, tag) in &[
        (512usize, 784usize, 200usize, "fc1_fwd"),
        (200, 512, 784, "fc1_bwd"),
        (512, 200, 10, "fc2_fwd"),
    ] {
        let a = Tensor::randn(&[m, k], &mut rng);
        let b = Tensor::randn(&[k, n], &mut rng);
        let flops = 2.0 * (m * k * n) as f64;
        suite.case(&format!("gemm/{tag}_{m}x{k}x{n}"), Some(flops), || matmul(&a, &b));
    }
    {
        let a = Tensor::randn(&[200, 784], &mut rng);
        let x = Tensor::randn(&[784], &mut rng);
        suite.case("gemm/matvec_200x784", Some(2.0 * (200 * 784) as f64), || {
            matvec(&a, &x)
        });
        // large enough to take the pool-split row path (serve/inference)
        let big = Tensor::randn(&[2048, 2048], &mut rng);
        let xb = Tensor::randn(&[2048], &mut rng);
        suite.case("gemm/matvec_2048x2048", Some(2.0 * (2048 * 2048) as f64), || {
            matvec(&big, &xb)
        });
    }

    // transpose-variant kernels at the randomized-SVD projection /
    // reconstruction shapes — packed straight from the strided source
    {
        let a = Tensor::randn(&[200, 784], &mut rng);
        let q = Tensor::randn(&[200, 68], &mut rng);
        let flops_tn = 2.0 * (784 * 200 * 68) as f64;
        suite.case("gemm/tn_proj_784x200x68", Some(flops_tn), || matmul_tn(&a, &q));
        let us = Tensor::randn(&[200, 68], &mut rng);
        let v = Tensor::randn(&[784, 68], &mut rng);
        let flops_nt = 2.0 * (200 * 68 * 784) as f64;
        suite.case("gemm/nt_outer_200x68x784", Some(flops_nt), || matmul_nt(&us, &v));
    }

    // tall-skinny GEMM at QRR's actual shapes: the sketch Y = A·Ω
    // (200×784 · 784×k) and the basis update (784×k · k×k)
    for &k in &[20usize, 68] {
        let a = Tensor::randn(&[200, 784], &mut rng);
        let omega = Tensor::randn(&[784, k], &mut rng);
        suite.case(
            &format!("gemm/sketch_200x784x{k}"),
            Some(2.0 * (200 * 784 * k) as f64),
            || matmul(&a, &omega),
        );
        let y = Tensor::randn(&[784, k], &mut rng);
        let rk = Tensor::randn(&[k, k], &mut rng);
        suite.case(
            &format!("gemm/basis_784x{k}x{k}"),
            Some(2.0 * (784 * k * k) as f64),
            || matmul(&y, &rk),
        );
    }

    // the accumulate entry point C += A·B (no alloc+zero per product)
    {
        let a = Tensor::randn(&[512, 784], &mut rng);
        let b = Tensor::randn(&[784, 200], &mut rng);
        let mut c = Tensor::zeros(&[512, 200]);
        let flops = 2.0 * (512 * 784 * 200) as f64;
        suite.case("gemm/acc_fc1_512x784x200", Some(flops), move || {
            c.scale(0.0);
            gemm_acc(&mut c, &a, &b);
        });
    }

    // QR on the randomized-SVD intermediate shapes: the blocked
    // compact-WY path vs the scalar per-reflector reference
    let tall = Tensor::randn(&[784, 68], &mut rng);
    suite.case("qr/thin_784x68", None, || qr_thin(&tall));
    let mid = Tensor::randn(&[200, 68], &mut rng);
    suite.case("qr/thin_200x68", None, || qr_thin(&mid));
    suite.case("qr/thin_unblocked_784x68", None, || qr_thin_unblocked(&tall));

    // SVD engines on the MLP's big gradient
    svd_engine_cases(suite);

    // Tucker on the paper's conv shapes
    let conv = Tensor::randn(&[32, 16, 3, 3], &mut rng);
    let ranks = tucker_ranks(&[32, 16, 3, 3], 0.3);
    suite.case("tucker/compress_p0.3_32x16x3x3", None, || {
        compress_tucker(&conv, &ranks, SvdMethod::Auto)
    });
    let conv_big = Tensor::randn(&[128, 64, 3, 3], &mut rng);
    let ranks_big = tucker_ranks(&[128, 64, 3, 3], 0.3);
    suite.case("tucker/compress_p0.3_128x64x3x3", None, || {
        compress_tucker(&conv_big, &ranks_big, SvdMethod::Auto)
    });

    // LAQ quantizer + bit packing on the full MLP gradient length
    let n = 159_010;
    let flat = Tensor::randn(&[n], &mut rng);
    let prev = Tensor::zeros(&[n]);
    suite.case("quant/laq_beta8_159k", Some(n as f64), || quantize(&flat, &prev, 8));
    let codes: Vec<u32> = (0..n).map(|i| (i % 256) as u32).collect();
    suite.case("quant/pack_beta8_159k", Some(n as f64), || pack_codes(&codes, 8));
    let packed = pack_codes(&codes, 8);
    suite.case("quant/unpack_beta8_159k", Some(n as f64), || {
        unpack_codes(&packed, n, 8)
    });

    // the fused LAQ pass at a second grid width and the decode direction
    suite.case("quant/laq_fused_beta4_159k", Some(n as f64), || {
        quantize(&flat, &prev, 4)
    });
    let (msg8, _) = quantize(&flat, &prev, 8);
    suite.case("quant/laq_fused_dequant_beta8_159k", Some(n as f64), || {
        dequantize(&msg8, &prev)
    });

    // raw SIMD-layer primitives (dispatched at the process level) at an
    // L1-resident length and the flat MLP-gradient length
    {
        use crate::exec::simd;
        let big = Tensor::randn(&[n], &mut rng);
        suite.case("simd/dot_159k", Some(n as f64), || {
            simd::dot(flat.data(), big.data())
        });
        let xs = Tensor::randn(&[4096], &mut rng);
        let ys = Tensor::randn(&[4096], &mut rng);
        suite.case("simd/dot_4k", Some(4096.0), || simd::dot(xs.data(), ys.data()));
        let mut acc = Tensor::zeros(&[n]);
        suite.case("simd/axpy_159k", Some(n as f64), move || {
            simd::axpy(acc.data_mut(), 0.5, big.data())
        });
        let mut acc4 = Tensor::zeros(&[4096]);
        suite.case("simd/axpy_4k", Some(4096.0), move || {
            simd::axpy(acc4.data_mut(), 0.5, ys.data())
        });
    }

    // wire encode/decode across all four entry kinds
    let shapes = vec![vec![200usize, 784], vec![200], vec![10, 200], vec![10]];
    let grads: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect();
    wire_cases(suite, "sgd_mlp", &ClientUpdate::Sgd { grads: grads.clone() });
    let slaq_params = grads
        .iter()
        .map(|g| quantize(g, &Tensor::zeros(g.shape()), 8).0)
        .collect();
    wire_cases(
        suite,
        "slaq_mlp",
        &ClientUpdate::Slaq { msg: SlaqMsg { params: slaq_params } },
    );
    let mut svd_codec = ClientCodec::new(&[vec![200, 784]], QrrConfig::with_p(0.2));
    wire_cases(
        suite,
        "qrr_svd",
        &ClientUpdate::Qrr { msgs: svd_codec.encode(std::slice::from_ref(&grads[0])) },
    );
    let conv_shapes = vec![vec![32usize, 16, 3, 3]];
    let conv_grad = vec![Tensor::randn(&[32, 16, 3, 3], &mut rng)];
    let mut tucker_codec = ClientCodec::new(&conv_shapes, QrrConfig::with_p(0.3));
    wire_cases(
        suite,
        "qrr_tucker",
        &ClientUpdate::Qrr { msgs: tucker_codec.encode(&conv_grad) },
    );
    // streamed framing: encode every per-layer chunk frame, then decode
    // and reassemble them — the full chunked wire cycle one client costs
    // per round in streaming mode (DESIGN.md §13)
    {
        let mut chunk_codec = ClientCodec::new(&shapes, QrrConfig::with_p(0.2));
        let update = ClientUpdate::Qrr { msgs: chunk_codec.encode(&grads) };
        let bytes_per = (update.payload_bits() / 8) as f64;
        suite.case("wire/chunk_encode_decode", Some(bytes_per), move || {
            let frames = Encoder::chunk_frames(&update, 0, 0);
            let mut bodies = Vec::with_capacity(frames.len());
            let mut scheme = 0u8;
            for f in &frames {
                let (h, b) = Decoder::decode_chunk(f).expect("bench chunk decode");
                scheme = h.scheme;
                bodies.push(b);
            }
            Decoder::assemble_update(scheme, bodies).expect("bench chunk assemble")
        });
    }

    // full QRR client encode / server decode (MLP shapes, p=0.2),
    // serial and fanned over the pool
    let mut codec = ClientCodec::new(&shapes, QrrConfig::with_p(0.2));
    suite.case("qrr/encode_mlp_p0.2", None, || codec.encode(&grads));
    let pool = crate::exec::ThreadPool::default_size();
    let mut codec_pooled = ClientCodec::new(&shapes, QrrConfig::with_p(0.2));
    suite.case("qrr/encode_mlp_p0.2_pooled", None, || {
        codec_pooled.encode_on(&grads, &pool)
    });
    let mut enc = ClientCodec::new(&shapes, QrrConfig::with_p(0.2));
    let msgs = enc.encode(&grads);
    let mut dec = ServerCodec::new(&shapes, QrrConfig::with_p(0.2));
    suite.case("qrr/decode_mlp_p0.2", None, || dec.decode(&msgs));
    let mut dec_pooled = ServerCodec::new(&shapes, QrrConfig::with_p(0.2));
    suite.case("qrr/decode_mlp_p0.2_pooled", None, || {
        dec_pooled.decode_on(&msgs, &pool)
    });

    // downlink codec at the MLP's shapes: delta-encode the broadcast
    // (svd+laq through the pipeline) and the client-side reconstruction
    {
        use crate::compress::pipeline::{DownlinkDecoder, DownlinkEncoder, PipelineSpec};
        let spec = PipelineSpec::parse("svd(p=0.1)+laq(beta=8)").expect("bench spec");
        let init: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect();
        // alternate between two parameter sets so every encode sees a
        // real (non-vanishing) delta
        let mut params_a = init.clone();
        let mut params_b = init.clone();
        for (a, b) in params_a.iter_mut().zip(params_b.iter_mut()) {
            a.axpy(0.05, &Tensor::randn(a.shape(), &mut rng));
            b.axpy(0.05, &Tensor::randn(b.shape(), &mut rng));
        }
        // pre-encode one broadcast for the decode case before the encode
        // closure takes ownership of the parameter sets
        let mut enc2 = DownlinkEncoder::new(&spec, &shapes, &init).expect("bench downlink");
        let mut upd = enc2.encode(&params_a, 0);
        let mut dec = DownlinkDecoder::new(&spec, &shapes, &init).expect("bench downlink");
        let mut seq = 0u64;
        suite.case("codec/downlink_decode", None, move || {
            // fresh sequence number per apply: the decoder enforces
            // exactly-once, in-order delivery
            upd.seq = seq;
            seq += 1;
            dec.apply(&upd).expect("bench decode");
        });
        let mut enc = DownlinkEncoder::new(&spec, &shapes, &init).expect("bench downlink");
        let mut flip = false;
        suite.case("codec/downlink_encode", None, move || {
            flip = !flip;
            enc.encode(if flip { &params_a } else { &params_b }, 0)
        });
    }

    // native model grad step (the L3-side compute baseline)
    let model = NativeModel::new(ModelKind::Mlp);
    let spec = ModelSpec::new(ModelKind::Mlp);
    let params = spec.init_params(1);
    let x = Tensor::randn(&[128, 784], &mut rng);
    let y: Vec<u32> = (0..128).map(|i| (i % 10) as u32).collect();
    suite.case("model/mlp_grad_b128", None, || model.loss_grad(&params, &x, &y));
}

/// Encode + decode cases for one wire entry kind. The encode case runs
/// through [`Encoder::encode_into`] with a persistent buffer — the
/// zero-allocation reuse path (the round loop itself takes the
/// one-exact-allocation [`Encoder::new`] path, since each upload owns
/// its bytes).
fn wire_cases(suite: &mut Suite, tag: &str, update: &ClientUpdate) {
    let bytes_per = (update.payload_bits() / 8) as f64;
    let mut buf = Vec::new();
    suite.case(&format!("wire/encode_{tag}"), Some(bytes_per), || {
        Encoder::encode_into(update, 0, 0, &mut buf);
    });
    let bytes = Encoder::new(update, 0, 0);
    suite.case(&format!("wire/decode_{tag}"), Some(bytes_per), || {
        Decoder::decode(&bytes).unwrap()
    });
}

// --------------------------------------------------------------- round

/// Register the `round` suite: one case per scheme × participation, each
/// measuring a full `FlSession::step` (broadcast → parallel client
/// compute → transport → decode → aggregate → descent) on the in-proc
/// transport at a reduced-but-real scale.
pub fn round_cases(suite: &mut Suite) {
    let schemes = [
        ("sgd", SchemeConfig::Sgd),
        ("slaq", SchemeConfig::Slaq),
        ("qrr_p0.2", SchemeConfig::Qrr(PPolicy::Fixed(0.2))),
    ];
    let parts = [
        ("full", ParticipationConfig::Full),
        ("uniform0.5", ParticipationConfig::Uniform { fraction: 0.5 }),
    ];
    let bench_cfg = |scheme, participation| {
        let mut cfg = ExperimentConfig::table1_default();
        cfg.scheme = scheme;
        cfg.participation = participation;
        cfg.clients = 4;
        cfg.batch = 16;
        cfg.train_n = 512;
        cfg.test_n = 64;
        cfg.eval_every = u64::MAX; // never evaluate inside the bench
        cfg.lr_schedule = vec![(0, 0.01)];
        cfg
    };
    // each case primes one round first so the uplink/downlink bit
    // accounting of a representative round rides along in the JSON
    // (`extras`: bits_up / bits_down / ratio) next to the timing
    fn run_case(suite: &mut Suite, name: &str, cfg: &ExperimentConfig) {
        if !suite.enabled(name) {
            // building the session is the expensive part; respect the
            // --only filter before paying for it (Suite::case would
            // skip anyway)
            suite.case(name, Some(1.0), || ());
            return;
        }
        let mut session = FlSessionBuilder::new(cfg).quiet().build().expect("bench session");
        session.step(0).expect("bench prime step");
        let r0 = session.history().rounds[0].clone();
        let mut it = 1u64;
        suite.case(name, Some(1.0), move || {
            session.step(it).expect("bench step");
            it += 1;
        });
        suite.annotate_last(vec![
            ("bits_up".into(), r0.bits as f64),
            ("bits_down".into(), r0.down_bits as f64),
            ("ratio".into(), r0.ratio),
        ]);
    }
    for (s_label, scheme) in schemes {
        for (p_label, participation) in parts {
            let cfg = bench_cfg(scheme, participation);
            run_case(suite, &format!("round/{s_label}/{p_label}"), &cfg);
        }
    }
    // dual-side: the same QRR round with the broadcast delta-encoded
    // through the downlink pipeline (perf gate covers the new path)
    {
        let mut cfg = bench_cfg(SchemeConfig::Qrr(PPolicy::Fixed(0.2)), ParticipationConfig::Full);
        cfg.downlink = Some(
            crate::compress::pipeline::PipelineSpec::parse("svd(p=0.1)+laq(beta=8)")
                .expect("bench spec"),
        );
        run_case(suite, "round/qrr_p0.2+downlink/full", &cfg);
    }
    // streamed rounds: the same dual-side QRR round with chunked
    // per-layer uplink framing, decode-on-arrival reassembly and the
    // double-buffered broadcast (DESIGN.md §13) — the perf gate pins the
    // overlap win against the sequential row above
    {
        let mut cfg = bench_cfg(SchemeConfig::Qrr(PPolicy::Fixed(0.2)), ParticipationConfig::Full);
        cfg.downlink = Some(
            crate::compress::pipeline::PipelineSpec::parse("svd(p=0.1)+laq(beta=8)")
                .expect("bench spec"),
        );
        cfg.streaming = true;
        run_case(suite, "round/streaming/full", &cfg);
    }
    // adaptive control plane: the linkaware controller re-plans each
    // client's uplink per round, so the step includes the observation →
    // spec decide path plus any pipeline swap (cached compiles after
    // round 1 — the steady-state cost the perf gate should see)
    {
        let mut cfg = bench_cfg(SchemeConfig::Sgd, ParticipationConfig::Full);
        cfg.controller = Some(crate::control::ControllerConfig::linkaware());
        run_case(suite, "round/adaptive_linkaware", &cfg);
    }
    // cohort scale: one full 10k-client round through the sharded
    // aggregation path alone (no client compute) — pre-encoded tiny SGD
    // frames dispatched to shard lanes, absorbed on arrival, partial
    // sums tree-reduced at close. This is the O(shards)-memory server
    // loop the scale CI job gates (DESIGN.md §10); units are
    // clients/sec.
    {
        let name = "round/scale_10k";
        if suite.enabled(name) {
            let n_clients = 10_000usize;
            let n_shards = 8usize;
            let shapes: Vec<Vec<usize>> = vec![vec![16, 8], vec![16]];
            let mut rng = Rng::new(0x10_000);
            let frames: Vec<Vec<u8>> = (0..n_clients)
                .map(|id| {
                    let grads: Vec<Tensor> =
                        shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect();
                    Encoder::new(&ClientUpdate::Sgd { grads }, id as u32, 0)
                })
                .collect();
            let schemes = (0..n_clients)
                .map(|_| make_server_scheme(SchemeKind::Sgd, &shapes, 8))
                .collect();
            let mut agg = ShardedAggregator::new(schemes, shapes, n_shards);
            let weights = vec![1.0f32; n_clients];
            // prime one round so the peak-live bound rides along in the
            // JSON next to the timing
            agg.begin_round(&weights, true);
            for (id, frame) in frames.iter().enumerate() {
                agg.dispatch_frame(id, frame.clone());
            }
            let d0 = agg.close_round();
            suite.case(name, Some(n_clients as f64), move || {
                agg.begin_round(&weights, true);
                for (id, frame) in frames.iter().enumerate() {
                    agg.dispatch_frame(id, frame.clone());
                }
                agg.close_round().delivered.iter().filter(|&&d| d).count()
            });
            suite.annotate_last(vec![
                ("clients".into(), n_clients as f64),
                ("shards".into(), n_shards as f64),
                ("peak_live".into(), d0.peak_live as f64),
            ]);
        } else {
            // keep the skip line in the output for discoverability
            suite.case(name, Some(1.0), || ());
        }
    }
    // the same 10k-client round through the streamed path: every update
    // crosses as per-layer chunk frames, reassembled decode-on-arrival
    // on the shard lanes. Dispatch is contiguous per client (as one TCP
    // connection delivers it), so the O(shards) live-memory bound must
    // hold exactly as in whole-frame mode — asserted on the primed
    // round, annotated for the scale gate.
    {
        let name = "round/scale_10k_streamed";
        if suite.enabled(name) {
            let n_clients = 10_000usize;
            let n_shards = 8usize;
            let shapes: Vec<Vec<usize>> = vec![vec![16, 8], vec![16]];
            let mut rng = Rng::new(0x10_001);
            let frames: Vec<Vec<Vec<u8>>> = (0..n_clients)
                .map(|id| {
                    let grads: Vec<Tensor> =
                        shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect();
                    Encoder::chunk_frames(&ClientUpdate::Sgd { grads }, id as u32, 0)
                })
                .collect();
            let schemes = (0..n_clients)
                .map(|_| make_server_scheme(SchemeKind::Sgd, &shapes, 8))
                .collect();
            let mut agg = ShardedAggregator::new(schemes, shapes, n_shards);
            let weights = vec![1.0f32; n_clients];
            agg.begin_round(&weights, true);
            for (id, chunks) in frames.iter().enumerate() {
                for frame in chunks {
                    agg.dispatch_chunk(id, frame.clone());
                }
            }
            let d0 = agg.close_round();
            assert!(
                d0.peak_live <= n_shards,
                "streamed peak live {} exceeds shard bound {}",
                d0.peak_live,
                n_shards
            );
            assert_eq!(
                d0.delivered.iter().filter(|&&d| d).count(),
                n_clients,
                "streamed scale round incomplete"
            );
            suite.case(name, Some(n_clients as f64), move || {
                agg.begin_round(&weights, true);
                for (id, chunks) in frames.iter().enumerate() {
                    for frame in chunks {
                        agg.dispatch_chunk(id, frame.clone());
                    }
                }
                agg.close_round().delivered.iter().filter(|&&d| d).count()
            });
            suite.annotate_last(vec![
                ("clients".into(), n_clients as f64),
                ("shards".into(), n_shards as f64),
                ("peak_live".into(), d0.peak_live as f64),
            ]);
        } else {
            suite.case(name, Some(1.0), || ());
        }
    }
}

// ------------------------------------------------- shared table runner

/// The paper's lineup for tables I & II.
pub fn fixed_p_lineup() -> Vec<SchemeConfig> {
    vec![
        SchemeConfig::Sgd,
        SchemeConfig::Slaq,
        SchemeConfig::Qrr(PPolicy::Fixed(0.3)),
        SchemeConfig::Qrr(PPolicy::Fixed(0.2)),
        SchemeConfig::Qrr(PPolicy::Fixed(0.1)),
    ]
}

/// Reduced-scale run of one table's scheme lineup through the suite
/// runner; prints timings + the paper-shaped markdown table and the
/// QRR/SGD bit ratios. Scale with `QRR_BENCH_ITERS` (default 40).
pub fn run_table_bench(name: &str, base: ExperimentConfig, schemes: &[SchemeConfig]) {
    let iters: u64 = crate::util::env::bench_iters().unwrap_or(40);
    let mut suite = Suite::new(name, Bench::from_env());
    let mut rows: Vec<TableRow> = Vec::new();
    println!("== {name} (reduced: {iters} iterations; QRR_BENCH_ITERS to change) ==");
    for &scheme in schemes {
        let mut cfg = base.clone();
        cfg.scheme = scheme;
        cfg.iters = iters;
        cfg.eval_every = (iters / 4).max(1);
        let (report, timing) = suite.once(&format!("{name}/{}", scheme.label()), || {
            FlSessionBuilder::new(&cfg)
                .build()
                .expect("session")
                .run()
                .expect("run")
        });
        println!(
            "    {:>10.2} ms/iter",
            timing.median.as_secs_f64() * 1e3 / iters as f64
        );
        rows.push(report.history.table_row());
    }
    println!("\n{}", markdown_table(&rows));
    if let Some(sgd) = rows.iter().find(|r| r.algorithm == "SGD") {
        for r in rows.iter().filter(|r| r.algorithm.starts_with("QRR")) {
            println!(
                "{}: {:.2}% of SGD bits, accuracy {:+.2}%",
                r.algorithm,
                100.0 * r.bits as f64 / sgd.bits as f64,
                100.0 * (r.accuracy - sgd.accuracy)
            );
        }
    }
    println!();
    maybe_write_json(&suite.finish());
}

/// Run one standalone registry as a `cargo bench` binary would: build
/// the sampler from the env, execute the cases, optionally emit JSON.
pub fn run_standalone(name: &str, cases: impl FnOnce(&mut Suite)) -> SuiteReport {
    let mut suite = Suite::new(name, Bench::from_env());
    cases(&mut suite);
    let report = suite.finish();
    maybe_write_json(&report);
    report
}

/// Write `BENCH_<suite>.json` into `$QRR_BENCH_JSON` (a directory) when
/// that env var is set — the opt-in JSON trail for the `cargo bench`
/// binaries; `qrr bench` writes unconditionally.
pub fn maybe_write_json(report: &SuiteReport) {
    if let Some(dir) = crate::util::env::bench_json_dir() {
        let path = format!("{}/BENCH_{}.json", dir, report.suite);
        let write = || -> anyhow::Result<()> {
            std::fs::create_dir_all(&dir)
                .map_err(|e| anyhow::anyhow!("creating QRR_BENCH_JSON dir {dir}: {e}"))?;
            report.save(&path)
        };
        match write() {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("warning: {e}"),
        }
    }
}

// ----------------------------------------------------------------- cli

/// Dispatch `qrr bench [kernels|round|all] [--fast] [--out DIR]
/// [--check] [--threshold PCT] [--only SUBSTR]`.
///
/// Writes `BENCH_<suite>.json` into `--out` (default `.`). With
/// `--check`, the committed baseline stays untouched: the current run
/// is written next to it as `BENCH_<suite>.current.json`, per-case
/// deltas are reported, and the command exits non-zero if any case
/// regressed by more than the threshold (default 25%) — so a failing
/// gate never destroys the numbers it failed against. A missing
/// baseline bootstraps (the current run is recorded as the baseline
/// and the gate passes); an unreadable baseline is a hard error, not a
/// silent bootstrap. A baseline marked `"estimated": true` (hand-written
/// placeholder numbers, no measured run behind them) is diffed and
/// reported but never fails the gate — the deltas would be fiction.
/// `--only SUBSTR` restricts every suite to cases whose name contains
/// the substring; a filtered run never overwrites (or bootstraps) the
/// committed baseline — it is written as `BENCH_<suite>.partial.json`
/// instead.
pub fn run_cli(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    let fast = args.has_flag("fast") || crate::util::env::bench_fast();
    let out_dir = args.get("out").unwrap_or(".");
    let check = args.has_flag("check");
    let only = args.get("only").map(str::to_string);
    let threshold = args
        .get_parsed::<f64>("threshold")?
        .map(|pct| pct / 100.0)
        .unwrap_or(DEFAULT_THRESHOLD);
    let names: Vec<&str> = match which {
        "kernels" => vec!["kernels"],
        "round" => vec!["round"],
        "all" => vec!["kernels", "round"],
        other => anyhow::bail!("unknown bench suite {other:?} (kernels|round|all)"),
    };

    std::fs::create_dir_all(out_dir)
        .map_err(|e| anyhow::anyhow!("creating --out {out_dir}: {e}"))?;
    let mut regressed: Vec<String> = Vec::new();
    for name in names {
        let bench = if fast { Bench::fast() } else { Bench::default() };
        println!(
            "== qrr bench: {name} ({} mode, {} threads, simd {}, cpu {}) ==",
            if fast { "fast" } else { "full" },
            crate::exec::default_threads(),
            crate::exec::simd::level().label(),
            crate::exec::simd::cpu_features()
        );
        let mut suite = Suite::new(name, bench);
        suite.set_filter(only.clone());
        if let Some(needle) = &only {
            println!("   (--only: cases containing {needle:?})");
        }
        match name {
            "kernels" => kernel_cases(&mut suite),
            "round" => round_cases(&mut suite),
            _ => unreachable!(),
        }
        let report = suite.finish();
        let path = format!("{out_dir}/BENCH_{name}.json");
        if only.is_some() && !check {
            // a filtered run is partial by construction: never let it
            // replace the committed full baseline
            let partial = format!("{out_dir}/BENCH_{name}.partial.json");
            report.save(&partial)?;
            println!("wrote {partial} (--only run; baseline {path} untouched)");
        } else if !check {
            report.save(&path)?;
            println!("wrote {path}");
        } else if !std::path::Path::new(&path).exists() {
            if only.is_some() {
                let current = format!("{out_dir}/BENCH_{name}.current.json");
                report.save(&current)?;
                println!(
                    "no baseline at {path}; --only run written to {current} \
                     (a partial run is never recorded as the baseline)"
                );
            } else {
                report.save(&path)?;
                println!("no baseline at {path}; this run recorded as the new baseline");
            }
        } else {
            // a present-but-unreadable baseline must fail the gate
            // loudly instead of being silently replaced
            let base = SuiteReport::load(&path)?;
            let current = format!("{out_dir}/BENCH_{name}.current.json");
            report.save(&current)?;
            println!("wrote {current} (baseline {path} untouched)");
            if base.mode != report.mode {
                println!(
                    "note: baseline mode {:?} != current mode {:?}",
                    base.mode, report.mode
                );
            }
            if base.simd != report.simd || base.cpu != report.cpu {
                println!(
                    "note: baseline environment (simd {}, cpu {}) != current (simd {}, cpu {})",
                    base.simd, base.cpu, report.simd, report.cpu
                );
            }
            if base.estimated {
                println!(
                    "note: baseline {path} is an ESTIMATED placeholder, not a measured run — \
                     deltas below are informational and will not fail the gate; regenerate \
                     with `qrr bench {name} --out .` on the reference hardware to arm it"
                );
            }
            println!(
                "-- {name} vs committed baseline (threshold {:.0}%) --",
                100.0 * threshold
            );
            for d in report.diff(&base, threshold) {
                println!("{}", d.line());
                if d.class == DeltaClass::Regressed && !base.estimated {
                    regressed.push(d.name);
                }
            }
        }
        println!();
    }
    if !regressed.is_empty() {
        anyhow::bail!(
            "perf gate: {} case(s) regressed more than {:.0}% vs the committed baseline: {}",
            regressed.len(),
            100.0 * threshold,
            regressed.join(", ")
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_suite_runs_one_fast_grid_cell() {
        // smoke: one scheme × participation cell steps without error
        // under the fast sampler (the full grid is exercised by CI)
        let mut suite = Suite::new(
            "round_smoke",
            Bench {
                warmup: std::time::Duration::from_millis(1),
                budget: std::time::Duration::from_millis(10),
                max_samples: 2,
                ..Bench::fast()
            },
        );
        let mut cfg = ExperimentConfig::table1_default();
        cfg.scheme = SchemeConfig::Sgd;
        cfg.clients = 2;
        cfg.batch = 8;
        cfg.train_n = 64;
        cfg.test_n = 16;
        cfg.eval_every = u64::MAX;
        cfg.lr_schedule = vec![(0, 0.01)];
        let mut session = FlSessionBuilder::new(&cfg).quiet().build().unwrap();
        let mut it = 0u64;
        let r = suite.case("round_smoke/sgd/full", Some(1.0), move || {
            session.step(it).unwrap();
            it += 1;
        });
        assert!(r.median > std::time::Duration::ZERO);
        let report = suite.finish();
        assert_eq!(report.suite, "round_smoke");
        assert_eq!(report.cases.len(), 1);
    }

    #[test]
    fn cli_rejects_unknown_suite() {
        let args = Args::parse(["bench".to_string(), "nope".to_string()]);
        assert!(run_cli(&args).is_err());
    }

    #[test]
    fn cli_only_filter_skips_cases_and_spares_baseline() {
        // a filter matching nothing must skip every case (closures never
        // run, so this is fast) and must NOT write the baseline file
        let dir = std::env::temp_dir().join("qrr_bench_only_test");
        let _ = std::fs::remove_dir_all(&dir);
        let out = dir.to_str().unwrap().to_string();
        let args = Args::parse(
            ["bench", "round", "--fast", "--only", "zzz-no-such-case", "--out", &out]
                .map(String::from),
        );
        run_cli(&args).unwrap();
        assert!(!dir.join("BENCH_round.json").exists(), "baseline must stay untouched");
        let partial = dir.join("BENCH_round.partial.json");
        let report = SuiteReport::load(partial.to_str().unwrap()).unwrap();
        assert!(report.cases.is_empty(), "filtered-out cases must not be recorded");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn scale_case_respects_only_filter_registration() {
        // the scale_10k case registers (as a skip) even when filtered
        // out, and the session cases skip without building sessions
        let mut suite = Suite::new("round", Bench::fast());
        suite.set_filter(Some("no-match".into()));
        round_cases(&mut suite);
        assert!(suite.finish().cases.is_empty());
    }
}
