//! The composable compression-pipeline API (DESIGN.md §7).
//!
//! A pipeline is `rank-reduction × quantization × feedback`, the design
//! space the structured-update taxonomy of Konečný et al. spans and of
//! which the paper's QRR is one point:
//!
//! * [`RankReducer`] stages — [`Identity`], [`Svd`]`{p}`,
//!   [`Tucker`]`{p}` — decide per parameter tensor how ℂ factors it
//!   (each stage claims the shapes it applies to; unclaimed parameters
//!   stay dense).
//! * A [`Quantizer`] stage — [`Identity`] or [`Laq`]`{beta}` — decides
//!   whether factors travel as β-bit LAQ grids (with mirrored
//!   differential state on both sides) or as raw f32.
//! * A [`Feedback`] wrapper — `None` or `ErrorFeedback` — optionally
//!   re-injects the compression residual into the next round's input.
//! * A `lazy` wrapper adds the SLAQ skip rule (valid only on the plain
//!   `laq` pipeline, which is exactly the SLAQ comparator).
//!
//! Specs are written in a small grammar, e.g.
//! `"svd(p=0.1)+laq(beta=8)+ef"`, parseable from JSON config and the
//! CLI ([`PipelineSpec::parse`]); the legacy schemes are named presets
//! resolving through the same registry ([`presets`]):
//!
//! | preset | spec |
//! |---|---|
//! | `sgd` | `identity` |
//! | `slaq` | `laq(beta=8)+lazy` |
//! | `qrr` | `svd(p=0.3)+tucker(p=0.3)+laq(beta=8)` |
//! | `ef-qrr` | `svd(p=0.3)+tucker(p=0.3)+laq(beta=8)+ef` |
//!
//! [`CompressionPipeline::compile`] checks a spec against a model's
//! parameter shapes and vends the mirrored halves: a [`PipelineClient`]
//! (gradients in, wire update out) and a [`PipelineServer`] (wire update
//! in, reconstructed gradients out). The legacy presets produce wire
//! bytes bit-identical to the pre-pipeline scheme layer because the
//! halves are built on the same machinery (`qrr::ClientCodec`
//! state mirrors, `slaq::SlaqClient`).
//!
//! The same stages run on the **downlink**: [`DownlinkEncoder`] holds a
//! shadow of the clients' model, each round encodes the parameter delta
//! `θ_server − θ_shadow` through the pipeline into a versioned
//! [`ServerUpdate`] wire message, and advances the shadow by its own
//! reconstruction — so compression error feeds back into the next
//! round's delta (dual-side low-rank compression à la Qiao et al.).
//! [`DownlinkDecoder`] mirrors the state client-side and locally
//! reconstructs the model, so rounds never ship full-precision
//! parameters.

use anyhow::{bail, ensure, Result};

use crate::compress::{
    compress_svd, compress_tucker, decompress_svd, decompress_tucker, svd_rank, tucker_ranks,
    SvdCompressed, TuckerCompressed,
};
use crate::linalg::SvdMethod;
use crate::net::wire::{ClientUpdate, ServerUpdate};
use crate::qrr::{ClientCodec, EfClientCodec, ParamMsg, ParamState, QrrConfig, ServerCodec};
use crate::slaq::{SlaqClient, SlaqConfig, SlaqServerState};
use crate::tensor::Tensor;

// ------------------------------------------------------------- stages

/// A rank-reduction stage: decides, per parameter shape, how ℂ factors
/// the tensor. Stages are consulted in spec order; the first to return
/// a plan claims the parameter.
pub trait RankReducer: Send + Sync {
    /// Spec-grammar label, e.g. `svd(p=0.1)`.
    fn label(&self) -> String;

    /// The reduction plan for a parameter of `shape`, or `None` if this
    /// stage does not apply to it.
    fn plan(&self, shape: &[usize]) -> Option<ReducePlan>;
}

/// A quantization stage: decides how factor tensors travel.
pub trait Quantizer: Send + Sync {
    /// Spec-grammar label, e.g. `laq(beta=8)`.
    fn label(&self) -> String;

    /// Bits per element on the β-bit grid; `None` = full-precision f32.
    fn beta(&self) -> Option<u8>;
}

/// The do-nothing stage: as a reducer it claims every shape as dense
/// (no factorization); as a quantizer it sends raw f32.
#[derive(Debug)]
pub struct Identity;

impl RankReducer for Identity {
    fn label(&self) -> String {
        "identity".into()
    }

    fn plan(&self, _shape: &[usize]) -> Option<ReducePlan> {
        Some(ReducePlan::Dense)
    }
}

impl Quantizer for Identity {
    fn label(&self) -> String {
        "identity".into()
    }

    fn beta(&self) -> Option<u8> {
        None
    }
}

/// Truncated SVD at rank ν = ⌈p·min(m,n)⌉ for matrix parameters
/// (paper eq. (20)/(22)); does not apply to other ranks.
#[derive(Debug)]
pub struct Svd {
    /// fraction of the original rank retained, in (0, 1]
    pub p: f64,
}

impl RankReducer for Svd {
    fn label(&self) -> String {
        format!("svd(p={})", self.p)
    }

    fn plan(&self, shape: &[usize]) -> Option<ReducePlan> {
        (shape.len() == 2)
            .then(|| ReducePlan::Svd { nu: svd_rank(shape[0], shape[1], self.p) })
    }
}

/// Tucker/HOSVD at per-mode ranks rᵢ = ⌈p·Iᵢ⌉ for parameters of 3+
/// modes (paper eq. (21)/(23)).
#[derive(Debug)]
pub struct Tucker {
    /// fraction of each mode's rank retained, in (0, 1]
    pub p: f64,
}

impl RankReducer for Tucker {
    fn label(&self) -> String {
        format!("tucker(p={})", self.p)
    }

    fn plan(&self, shape: &[usize]) -> Option<ReducePlan> {
        (shape.len() >= 3).then(|| ReducePlan::Tucker { ranks: tucker_ranks(shape, self.p) })
    }
}

/// The LAQ β-bit grid quantizer (paper §II-B) with mirrored
/// differential state per factor. The grids are computed by the fused
/// SIMD sweep in [`crate::exec::simd`] (radius scan + branchless code
/// and reconstruction in one pass, DESIGN.md §8); codes are identical
/// on every dispatch level, so pipeline wire bytes never depend on
/// `QRR_SIMD`.
#[derive(Debug)]
pub struct Laq {
    /// bits per element, 1..=16
    pub beta: u8,
}

impl Quantizer for Laq {
    fn label(&self) -> String {
        format!("laq(beta={})", self.beta)
    }

    fn beta(&self) -> Option<u8> {
        Some(self.beta)
    }
}

/// Whether the client re-injects its compression residual into the next
/// round's gradient before compressing (Seide et al. / Karimireddy et
/// al. error feedback).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Feedback {
    /// compression error is dropped (the paper's plain QRR)
    #[default]
    None,
    /// residual accumulates and is re-sent (`+ef` in the grammar)
    ErrorFeedback,
}

/// Compiled per-parameter reduction plan.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ReducePlan {
    /// no factorization; the tensor is (possibly quantized and) sent whole
    Dense,
    /// truncated SVD at rank ν
    Svd {
        /// retained rank
        nu: usize,
    },
    /// Tucker at per-mode ranks
    Tucker {
        /// retained per-mode ranks
        ranks: Vec<usize>,
    },
}

// ---------------------------------------------------------------- spec

/// A rank-reducer stage in a [`PipelineSpec`].
#[derive(Debug, Clone, PartialEq)]
pub enum ReducerSpec {
    /// truncated SVD for matrices
    Svd {
        /// retained rank fraction, in (0, 1]
        p: f64,
    },
    /// Tucker for 3+-mode tensors
    Tucker {
        /// retained per-mode rank fraction, in (0, 1]
        p: f64,
    },
}

impl ReducerSpec {
    /// Instantiate the stage behind this spec (the boxed form for
    /// callers composing `dyn RankReducer` stages).
    pub fn stage(&self) -> Box<dyn RankReducer> {
        match *self {
            ReducerSpec::Svd { p } => Box::new(Svd { p }),
            ReducerSpec::Tucker { p } => Box::new(Tucker { p }),
        }
    }

    /// The stage's grammar label, without allocating a trait object.
    pub fn label(&self) -> String {
        match *self {
            ReducerSpec::Svd { p } => Svd { p }.label(),
            ReducerSpec::Tucker { p } => Tucker { p }.label(),
        }
    }

    /// The stage's plan for `shape`, without allocating a trait object.
    pub fn plan(&self, shape: &[usize]) -> Option<ReducePlan> {
        match *self {
            ReducerSpec::Svd { p } => Svd { p }.plan(shape),
            ReducerSpec::Tucker { p } => Tucker { p }.plan(shape),
        }
    }

    fn p(&self) -> f64 {
        match *self {
            ReducerSpec::Svd { p } | ReducerSpec::Tucker { p } => p,
        }
    }
}

/// A quantizer stage in a [`PipelineSpec`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantizerSpec {
    /// LAQ β-bit grids with mirrored differential state
    Laq {
        /// bits per element, 1..=16
        beta: u8,
    },
}

impl QuantizerSpec {
    /// Instantiate the stage behind this spec (the boxed form for
    /// callers composing `dyn Quantizer` stages).
    pub fn stage(&self) -> Box<dyn Quantizer> {
        match *self {
            QuantizerSpec::Laq { beta } => Box::new(Laq { beta }),
        }
    }

    /// The stage's grammar label, without allocating a trait object.
    pub fn label(&self) -> String {
        match *self {
            QuantizerSpec::Laq { beta } => Laq { beta }.label(),
        }
    }
}

/// A parsed, validated compression-pipeline description.
///
/// Build one from the grammar with [`PipelineSpec::parse`], or from the
/// preset constructors ([`sgd`](Self::sgd), [`slaq`](Self::slaq),
/// [`qrr`](Self::qrr), [`qrr_ef`](Self::qrr_ef)). [`format`](Self::format)
/// renders the canonical spec string; `parse ∘ format` is the identity.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct PipelineSpec {
    /// rank-reduction stages, consulted in order per parameter
    pub reducers: Vec<ReducerSpec>,
    /// the quantizer stage; `None` = identity (raw f32 factors)
    pub quantizer: Option<QuantizerSpec>,
    /// the feedback wrapper
    pub feedback: Feedback,
    /// the SLAQ lazy-skip wrapper (`+lazy`; plain-`laq` pipelines only)
    pub lazy: bool,
}

impl PipelineSpec {
    /// The `sgd` preset: identity pipeline, full-precision gradients.
    pub fn sgd() -> Self {
        PipelineSpec::default()
    }

    /// The `slaq` preset: `laq(beta=β)+lazy`.
    pub fn slaq(beta: u8) -> Self {
        PipelineSpec {
            quantizer: Some(QuantizerSpec::Laq { beta }),
            lazy: true,
            ..Default::default()
        }
    }

    /// The `qrr` preset: `svd(p)+tucker(p)+laq(beta=β)` — the paper's
    /// scheme (SVD for matrices, Tucker for conv kernels, biases
    /// quantize-only).
    pub fn qrr(p: f64, beta: u8) -> Self {
        PipelineSpec {
            reducers: vec![ReducerSpec::Svd { p }, ReducerSpec::Tucker { p }],
            quantizer: Some(QuantizerSpec::Laq { beta }),
            ..Default::default()
        }
    }

    /// The `ef-qrr` preset: [`qrr`](Self::qrr) plus error feedback.
    pub fn qrr_ef(p: f64, beta: u8) -> Self {
        PipelineSpec { feedback: Feedback::ErrorFeedback, ..Self::qrr(p, beta) }
    }

    /// Parse a spec string: a preset name (`sgd`, `slaq`, `qrr`,
    /// `ef-qrr`, optionally with `(p=…,beta=…)` arguments) or a `+`-joined
    /// stage list over `identity` / `svd(p=…)` / `tucker(p=…)` /
    /// `laq(beta=…)` / `ef` / `lazy`.
    pub fn parse(s: &str) -> Result<Self> {
        let s = s.trim();
        ensure!(!s.is_empty(), "empty pipeline spec");
        if let Some(spec) = Self::parse_preset(s)? {
            return Ok(spec);
        }
        let mut spec = PipelineSpec::default();
        let mut saw_identity = false;
        let mut n_stages = 0usize;
        for tok in s.split('+') {
            let tok = tok.trim();
            ensure!(!tok.is_empty(), "empty stage in {s:?} (trailing or doubled '+')");
            n_stages += 1;
            let (name, args) = split_stage(tok)?;
            match name {
                "identity" => {
                    ensure!(args.is_empty(), "identity takes no arguments");
                    saw_identity = true;
                }
                "svd" => {
                    ensure!(
                        !spec.reducers.iter().any(|r| matches!(r, ReducerSpec::Svd { .. })),
                        "duplicate svd stage"
                    );
                    spec.reducers.push(ReducerSpec::Svd { p: arg_p(&args, tok)? });
                }
                "tucker" => {
                    ensure!(
                        !spec.reducers.iter().any(|r| matches!(r, ReducerSpec::Tucker { .. })),
                        "duplicate tucker stage"
                    );
                    spec.reducers.push(ReducerSpec::Tucker { p: arg_p(&args, tok)? });
                }
                "laq" => {
                    ensure!(spec.quantizer.is_none(), "duplicate laq stage");
                    spec.quantizer = Some(QuantizerSpec::Laq { beta: arg_beta(&args, tok)? });
                }
                "ef" => {
                    ensure!(args.is_empty(), "ef takes no arguments");
                    ensure!(spec.feedback == Feedback::None, "duplicate ef stage");
                    spec.feedback = Feedback::ErrorFeedback;
                }
                "lazy" => {
                    ensure!(args.is_empty(), "lazy takes no arguments");
                    ensure!(!spec.lazy, "duplicate lazy stage");
                    spec.lazy = true;
                }
                other => bail!(
                    "unknown stage {other:?} (identity | svd(p=..) | tucker(p=..) | \
                     laq(beta=..) | ef | lazy, or a preset: sgd | slaq | qrr | ef-qrr)"
                ),
            }
        }
        if saw_identity {
            ensure!(
                n_stages == 1,
                "identity must be the whole pipeline, not combined with other stages"
            );
        }
        spec.validate()?;
        Ok(spec)
    }

    fn parse_preset(s: &str) -> Result<Option<Self>> {
        let (name, args) = match split_stage(s) {
            Ok(x) => x,
            Err(_) => return Ok(None),
        };
        let spec = match name {
            "sgd" => {
                ensure!(args.is_empty(), "sgd takes no arguments");
                Self::sgd()
            }
            "slaq" => Self::slaq(arg_beta_or(&args, 8, &["beta"], s)?),
            "qrr" => Self::qrr(
                arg_p_or(&args, 0.3, &["p", "beta"], s)?,
                arg_beta_or(&args, 8, &["p", "beta"], s)?,
            ),
            "ef-qrr" | "qrr-ef" => Self::qrr_ef(
                arg_p_or(&args, 0.3, &["p", "beta"], s)?,
                arg_beta_or(&args, 8, &["p", "beta"], s)?,
            ),
            _ => return Ok(None),
        };
        Ok(Some(spec))
    }

    /// Range and composition checks (also run by [`parse`](Self::parse)).
    pub fn validate(&self) -> Result<()> {
        for r in &self.reducers {
            let p = r.p();
            ensure!(
                p > 0.0 && p <= 1.0 && p.is_finite(),
                "rank fraction p must be in (0,1], got {p}"
            );
        }
        if let Some(QuantizerSpec::Laq { beta }) = self.quantizer {
            ensure!((1..=16).contains(&beta), "laq beta must be in 1..=16, got {beta}");
        }
        if self.feedback == Feedback::ErrorFeedback {
            ensure!(
                self.quantizer.is_some(),
                "ef requires the laq quantizer (raw-f32 pipelines keep no residual state)"
            );
        }
        if self.lazy {
            ensure!(
                self.quantizer.is_some() && self.reducers.is_empty(),
                "lazy (the SLAQ skip rule) applies only to the plain laq pipeline"
            );
            ensure!(
                self.feedback == Feedback::None,
                "lazy and ef cannot be combined"
            );
        }
        Ok(())
    }

    /// The canonical spec string; [`parse`](Self::parse) inverts it.
    pub fn format(&self) -> String {
        let mut parts: Vec<String> = self.reducers.iter().map(|r| r.label()).collect();
        if let Some(q) = &self.quantizer {
            parts.push(q.label());
        }
        if self.feedback == Feedback::ErrorFeedback {
            parts.push("ef".into());
        }
        if self.lazy {
            parts.push("lazy".into());
        }
        if parts.is_empty() {
            return "identity".into();
        }
        parts.join("+")
    }

    /// The headline telemetry knobs `(p, beta)`: the smallest reducer
    /// rank fraction (`1.0` when nothing factorizes) and the quantizer
    /// bits (`32` = raw f32). What the per-client metrics CSV records
    /// and the `control::` policies steer.
    pub fn knobs(&self) -> (f64, u8) {
        let p = self.reducers.iter().map(|r| r.p()).fold(1.0, f64::min);
        (p, self.beta().unwrap_or(32))
    }

    /// True for the all-identity pipeline (the `sgd` preset).
    pub fn is_identity(&self) -> bool {
        self.reducers.is_empty() && self.quantizer.is_none() && !self.lazy
    }

    /// [`validate`](Self::validate) plus the downlink-specific rules:
    /// `lazy` is an uplink policy, and `ef` is redundant because the
    /// delta-vs-shadow encoding already feeds compression error back.
    /// The single source of truth for every downlink entry point
    /// (config JSON, CLI overrides, [`DownlinkEncoder`]/[`DownlinkDecoder`]).
    pub fn validate_downlink(&self) -> Result<()> {
        self.validate()?;
        ensure!(!self.lazy, "the lazy skip rule is an uplink policy; invalid on the downlink");
        ensure!(
            self.feedback == Feedback::None,
            "downlink deltas are encoded against a shadow model, which already \
             feeds compression error back; drop the explicit +ef"
        );
        Ok(())
    }

    fn beta(&self) -> Option<u8> {
        self.quantizer.map(|q| match q {
            QuantizerSpec::Laq { beta } => beta,
        })
    }

    /// The plan the reducer stages produce for one parameter shape.
    fn plan_for(&self, shape: &[usize]) -> ReducePlan {
        for r in &self.reducers {
            if let Some(plan) = r.plan(shape) {
                return plan;
            }
        }
        ReducePlan::Dense
    }
}

fn split_stage(tok: &str) -> Result<(&str, Vec<(String, String)>)> {
    match tok.split_once('(') {
        None => {
            ensure!(
                tok.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_'),
                "malformed stage {tok:?}"
            );
            Ok((tok, Vec::new()))
        }
        Some((name, rest)) => {
            let inner = rest
                .strip_suffix(')')
                .ok_or_else(|| anyhow::anyhow!("unclosed '(' in stage {tok:?}"))?;
            let mut args = Vec::new();
            for kv in inner.split(',') {
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| anyhow::anyhow!("expected key=value in {tok:?}, got {kv:?}"))?;
                args.push((k.trim().to_string(), v.trim().to_string()));
            }
            Ok((name, args))
        }
    }
}

/// Reject any argument key this stage/preset does not accept, then look
/// up `key`. Each call site passes exactly the keys it understands, so
/// e.g. `svd(p=0.1,beta=4)` fails loudly instead of silently dropping
/// the beta the user thought they set.
fn arg<'a>(
    args: &'a [(String, String)],
    key: &str,
    allowed: &[&str],
    tok: &str,
) -> Result<Option<&'a str>> {
    for (k, _) in args {
        ensure!(
            allowed.iter().any(|a| a == k),
            "unknown argument {k:?} in {tok:?} (accepted: {})",
            allowed.join(", ")
        );
    }
    Ok(args.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str()))
}

fn arg_p(args: &[(String, String)], tok: &str) -> Result<f64> {
    arg(args, "p", &["p"], tok)?
        .ok_or_else(|| anyhow::anyhow!("{tok:?} requires p=<fraction>"))?
        .parse::<f64>()
        .map_err(|_| anyhow::anyhow!("bad p in {tok:?}"))
}

fn arg_p_or(args: &[(String, String)], default: f64, allowed: &[&str], tok: &str) -> Result<f64> {
    match arg(args, "p", allowed, tok)? {
        None => Ok(default),
        Some(v) => v.parse::<f64>().map_err(|_| anyhow::anyhow!("bad p in {tok:?}")),
    }
}

fn arg_beta(args: &[(String, String)], tok: &str) -> Result<u8> {
    arg(args, "beta", &["beta"], tok)?
        .ok_or_else(|| anyhow::anyhow!("{tok:?} requires beta=<bits>"))?
        .parse::<u8>()
        .map_err(|_| anyhow::anyhow!("bad beta in {tok:?}"))
}

fn arg_beta_or(args: &[(String, String)], default: u8, allowed: &[&str], tok: &str) -> Result<u8> {
    match arg(args, "beta", allowed, tok)? {
        None => Ok(default),
        Some(v) => v.parse::<u8>().map_err(|_| anyhow::anyhow!("bad beta in {tok:?}")),
    }
}

// ------------------------------------------------------------ registry

/// One registered preset: a name resolving to a full spec.
#[derive(Debug)]
pub struct PresetInfo {
    /// registry name (what configs/CLI write)
    pub name: &'static str,
    /// the spec the name resolves to (default parameters)
    pub spec: String,
    /// one-line description
    pub summary: &'static str,
}

/// The preset registry: every legacy scheme as a named pipeline.
pub fn presets() -> Vec<PresetInfo> {
    vec![
        PresetInfo {
            name: "sgd",
            spec: PipelineSpec::sgd().format(),
            summary: "full-precision federated averaging (paper baseline)",
        },
        PresetInfo {
            name: "slaq",
            spec: PipelineSpec::slaq(8).format(),
            summary: "lazily aggregated quantized gradients (paper comparator)",
        },
        PresetInfo {
            name: "qrr",
            spec: PipelineSpec::qrr(0.3, 8).format(),
            summary: "quantized rank reduction (the paper's scheme); args p, beta",
        },
        PresetInfo {
            name: "ef-qrr",
            spec: PipelineSpec::qrr_ef(0.3, 8).format(),
            summary: "QRR with client-side error feedback; args p, beta",
        },
    ]
}

/// One registered stage of the spec grammar.
#[derive(Debug)]
pub struct StageInfo {
    /// grammar form
    pub signature: &'static str,
    /// one-line description
    pub summary: &'static str,
}

/// The stage registry backing the spec grammar.
pub fn stages() -> Vec<StageInfo> {
    vec![
        StageInfo {
            signature: "identity",
            summary: "no compression (must be the whole pipeline)",
        },
        StageInfo {
            signature: "svd(p=<frac>)",
            summary: "truncated SVD at rank ceil(p*min(m,n)) for matrix parameters",
        },
        StageInfo {
            signature: "tucker(p=<frac>)",
            summary: "Tucker/HOSVD at ranks ceil(p*I_i) for 3+-mode parameters",
        },
        StageInfo {
            signature: "laq(beta=<bits>)",
            summary: "LAQ beta-bit grid quantizer with mirrored differential state",
        },
        StageInfo {
            signature: "ef",
            summary: "error feedback: residual re-injected next round (needs laq)",
        },
        StageInfo {
            signature: "lazy",
            summary: "SLAQ lazy-skip rule (plain laq pipelines only)",
        },
    ]
}

// ------------------------------------------------------------ compile

/// Client-side build context: parameters the SLAQ lazy rule needs.
#[derive(Debug, Clone, Copy)]
pub struct BuildCtx {
    /// learning rate α (enters the SLAQ skip threshold)
    pub alpha: f32,
    /// number of clients C (enters the SLAQ skip threshold)
    pub clients: usize,
}

/// A spec compiled against a model's parameter shapes; vends the
/// mirrored [`PipelineClient`] / [`PipelineServer`] halves.
#[derive(Debug)]
pub struct CompressionPipeline {
    spec: PipelineSpec,
    label: String,
    shapes: Vec<Vec<usize>>,
    plans: Vec<ReducePlan>,
    /// identity spec ⇒ emit the legacy full-precision `Sgd` wire form
    passthrough: bool,
}

impl CompressionPipeline {
    /// Validate `spec` and compile its per-parameter plans over `shapes`.
    pub fn compile(spec: PipelineSpec, shapes: &[Vec<usize>]) -> Result<Self> {
        spec.validate()?;
        let plans = shapes.iter().map(|s| spec.plan_for(s)).collect();
        Ok(CompressionPipeline {
            label: spec.format(),
            passthrough: spec.is_identity(),
            spec,
            shapes: shapes.to_vec(),
            plans,
        })
    }

    /// Compile from **custom stage objects** instead of a parsed spec —
    /// the extensibility seam behind the [`RankReducer`] trait: the
    /// boxed stages are consulted in order exactly like spec stages, so
    /// a third-party reducer can claim shapes with its own policy (the
    /// plan vocabulary stays [`ReducePlan`], which fixes the wire
    /// format). Quantizer and feedback still come from the closed spec
    /// vocabulary for the same reason. Parameters no stage claims stay
    /// dense; the resulting pipeline never takes the legacy `Sgd`
    /// passthrough (that wire form belongs to the `sgd` preset alone).
    pub fn compile_with(
        reducers: &[Box<dyn RankReducer>],
        quantizer: Option<QuantizerSpec>,
        feedback: Feedback,
        shapes: &[Vec<usize>],
    ) -> Result<Self> {
        let spec = PipelineSpec { reducers: Vec::new(), quantizer, feedback, lazy: false };
        spec.validate()?;
        let plans = shapes
            .iter()
            .map(|s| {
                reducers
                    .iter()
                    .find_map(|r| r.plan(s))
                    .unwrap_or(ReducePlan::Dense)
            })
            .collect();
        let mut parts: Vec<String> = reducers.iter().map(|r| r.label()).collect();
        if let Some(q) = &spec.quantizer {
            parts.push(q.label());
        }
        if spec.feedback == Feedback::ErrorFeedback {
            parts.push("ef".into());
        }
        let label = if parts.is_empty() { "identity".into() } else { parts.join("+") };
        Ok(CompressionPipeline {
            label,
            passthrough: false,
            spec,
            shapes: shapes.to_vec(),
            plans,
        })
    }

    /// The validated spec (custom-stage pipelines report an empty
    /// reducer list — their policy lives in the stages).
    pub fn spec(&self) -> &PipelineSpec {
        &self.spec
    }

    /// Display label: the canonical spec string, or the joined stage
    /// labels for a custom-stage pipeline.
    pub fn label(&self) -> &str {
        &self.label
    }

    /// Compiled per-parameter plans (tests / diagnostics).
    pub fn plans(&self) -> &[ReducePlan] {
        &self.plans
    }

    fn quant_states(&self) -> Vec<ParamState> {
        self.shapes
            .iter()
            .zip(self.plans.iter())
            .map(|(shape, plan)| match plan {
                ReducePlan::Dense => ParamState::planned_dense(shape),
                ReducePlan::Svd { nu } => ParamState::planned_svd(shape[0], shape[1], *nu),
                ReducePlan::Tucker { ranks } => ParamState::planned_tucker(shape, ranks.clone()),
            })
            .collect()
    }

    fn qrr_config(&self, beta: u8) -> QrrConfig {
        // p is display-only once states are planned
        let p = self.spec.reducers.first().map(|r| r.p()).unwrap_or(0.0);
        QrrConfig { p, beta, method: SvdMethod::Auto }
    }

    fn enc_core(&self) -> EncCore {
        match self.spec.beta() {
            None => EncCore::Raw(RawCodec {
                shapes: self.shapes.clone(),
                plans: self.plans.clone(),
                method: SvdMethod::Auto,
            }),
            Some(beta) => {
                let inner = ClientCodec::from_states(self.quant_states(), self.qrr_config(beta));
                match self.spec.feedback {
                    Feedback::None => EncCore::Laq(inner),
                    Feedback::ErrorFeedback => {
                        let mirror = ServerCodec::from_states(self.quant_states());
                        EncCore::LaqEf(EfClientCodec::from_parts(inner, mirror, &self.shapes))
                    }
                }
            }
        }
    }

    fn dec_core(&self) -> DecCore {
        match self.spec.beta() {
            None => DecCore::Raw(RawCodec {
                shapes: self.shapes.clone(),
                plans: self.plans.clone(),
                method: SvdMethod::Auto,
            }),
            // EF is server-transparent: same decoder as the plain pipeline
            Some(_) => DecCore::Laq(ServerCodec::from_states(self.quant_states())),
        }
    }

    /// The client half: gradients in, wire update out.
    pub fn client(&self, ctx: &BuildCtx) -> PipelineClient {
        let core = if self.passthrough {
            ClientCore::Sgd
        } else if self.spec.lazy {
            let beta = self.spec.beta().expect("lazy validated to require laq");
            ClientCore::Lazy(SlaqClient::new(
                &self.shapes,
                SlaqConfig { beta, ..SlaqConfig::paper(ctx.alpha, ctx.clients) },
            ))
        } else {
            ClientCore::Pipe(self.enc_core())
        };
        PipelineClient { label: self.label.clone(), core }
    }

    /// The server half: one instance per client, mirroring its state.
    pub fn server(&self) -> PipelineServer {
        let core = if self.passthrough {
            ServerCore::Sgd { shapes: self.shapes.clone() }
        } else if self.spec.lazy {
            ServerCore::Lazy(SlaqServerState::new(&self.shapes))
        } else {
            ServerCore::Pipe { core: self.dec_core(), shapes: self.shapes.clone() }
        };
        PipelineServer { label: self.label.clone(), core }
    }
}

// ---------------------------------------------------------- raw codec

/// Stateless codec for pipelines with the identity quantizer: factors
/// travel as raw f32, reconstruction needs no mirrored state.
#[derive(Debug, Clone)]
struct RawCodec {
    shapes: Vec<Vec<usize>>,
    plans: Vec<ReducePlan>,
    method: SvdMethod,
}

impl RawCodec {
    /// True when every message matches this codec's plans — kinds and
    /// factor dimensions — so [`decode`](Self::decode) cannot panic on
    /// externally controlled input.
    // qrr-audit: no-panic
    fn accepts(&self, msgs: &[ParamMsg]) -> bool {
        if msgs.len() != self.plans.len() {
            return false;
        }
        self.plans
            .iter()
            .zip(self.shapes.iter())
            .zip(msgs.iter())
            .all(|((plan, shape), msg)| match (plan, msg) {
                (ReducePlan::Dense, ParamMsg::RawDense { t }) => t.shape() == &shape[..],
                (ReducePlan::Svd { .. }, ParamMsg::RawSvd { u, s, v }) => {
                    u.ndim() == 2
                        && v.ndim() == 2
                        && s.ndim() == 1
                        && u.shape()[0] == shape[0]
                        && v.shape()[0] == shape[1]
                        && u.shape()[1] == s.len()
                        && v.shape()[1] == s.len()
                }
                (ReducePlan::Tucker { .. }, ParamMsg::RawTucker { core, factors }) => {
                    core.ndim() == shape.len()
                        && factors.len() == shape.len()
                        && factors.iter().enumerate().all(|(i, f)| {
                            f.ndim() == 2
                                && f.shape()[0] == shape[i]
                                && f.shape()[1] == core.shape()[i]
                        })
                }
                _ => false,
            })
    }
    // qrr-audit: end

    fn encode(&self, tensors: &[Tensor]) -> Vec<ParamMsg> {
        assert_eq!(tensors.len(), self.plans.len(), "tensor count mismatch");
        self.plans
            .iter()
            .zip(tensors.iter())
            .map(|(plan, t)| match plan {
                ReducePlan::Dense => ParamMsg::RawDense { t: t.clone() },
                ReducePlan::Svd { nu } => {
                    let c = compress_svd(t, *nu, self.method);
                    ParamMsg::RawSvd { u: c.u, s: Tensor::vector(c.s), v: c.v }
                }
                ReducePlan::Tucker { ranks } => {
                    let c = compress_tucker(t, ranks, self.method);
                    ParamMsg::RawTucker { core: c.core, factors: c.factors }
                }
            })
            .collect()
    }

    fn decode(&self, msgs: &[ParamMsg]) -> Vec<Tensor> {
        assert_eq!(msgs.len(), self.plans.len(), "message count mismatch");
        msgs.iter()
            .zip(self.shapes.iter())
            .map(|(msg, shape)| match msg {
                ParamMsg::RawDense { t } => t.clone(),
                ParamMsg::RawSvd { u, s, v } => decompress_svd(&SvdCompressed {
                    u: u.clone(),
                    s: s.data().to_vec(),
                    v: v.clone(),
                    shape: (shape[0], shape[1]),
                }),
                ParamMsg::RawTucker { core, factors } => decompress_tucker(&TuckerCompressed {
                    core: core.clone(),
                    factors: factors.clone(),
                    shape: shape.clone(),
                }),
                other => panic!("raw pipeline received quantized message {other:?}"),
            })
            .collect()
    }
}

// --------------------------------------------------------------- halves

#[derive(Debug)]
enum EncCore {
    Raw(RawCodec),
    Laq(ClientCodec),
    LaqEf(EfClientCodec),
}

impl EncCore {
    fn encode(&mut self, tensors: &[Tensor]) -> Vec<ParamMsg> {
        match self {
            EncCore::Raw(c) => c.encode(tensors),
            EncCore::Laq(c) => c.encode(tensors),
            EncCore::LaqEf(c) => c.encode(tensors),
        }
    }

    fn mem_bytes(&self) -> usize {
        match self {
            EncCore::Raw(_) => 0,
            EncCore::Laq(c) => c.mem_bytes(),
            EncCore::LaqEf(c) => c.mem_bytes(),
        }
    }
}

#[derive(Debug)]
enum DecCore {
    Raw(RawCodec),
    Laq(ServerCodec),
}

impl DecCore {
    /// Whether `msgs` matches this decoder's plans/states exactly (the
    /// no-panic precondition for [`decode`](Self::decode)).
    // qrr-audit: no-panic
    fn accepts(&self, msgs: &[ParamMsg]) -> bool {
        match self {
            DecCore::Raw(c) => c.accepts(msgs),
            DecCore::Laq(c) => c.accepts(msgs),
        }
    }
    // qrr-audit: end

    fn decode(&mut self, msgs: &[ParamMsg]) -> Vec<Tensor> {
        match self {
            DecCore::Raw(c) => c.decode(msgs),
            DecCore::Laq(c) => c.decode(msgs),
        }
    }

    fn mem_bytes(&self) -> usize {
        match self {
            DecCore::Raw(_) => 0,
            DecCore::Laq(c) => c.mem_bytes(),
        }
    }
}

#[derive(Debug)]
enum ClientCore {
    Sgd,
    Lazy(SlaqClient),
    Pipe(EncCore),
}

/// The client half of a compiled pipeline: this round's gradients in,
/// wire update out (`None` = lazily skipped).
#[derive(Debug)]
pub struct PipelineClient {
    label: String,
    core: ClientCore,
}

impl PipelineClient {
    /// The spec string this half was compiled from.
    pub fn label(&self) -> &str {
        &self.label
    }

    /// Produce this round's update. `weights` are the freshly broadcast
    /// parameters (the lazy rule observes them; other pipelines ignore
    /// them).
    pub fn produce(&mut self, weights: &[Tensor], grads: &[Tensor]) -> Option<ClientUpdate> {
        match &mut self.core {
            ClientCore::Sgd => Some(ClientUpdate::Sgd { grads: grads.to_vec() }),
            ClientCore::Lazy(c) => {
                c.observe_weights(weights);
                c.step(grads).map(|msg| ClientUpdate::Slaq { msg })
            }
            ClientCore::Pipe(core) => Some(ClientUpdate::Qrr { msgs: core.encode(grads) }),
        }
    }

    /// Streamed variant of [`produce`](Self::produce): encode the
    /// update, then hand each layer's chunk frame to `emit` the moment
    /// it is serialized (DESIGN.md §13) — the caller overlaps encode of
    /// layer *l+1* with transmit of layer *l* by sending inside `emit`.
    /// Returns the update's whole-message `payload_bits` (`None` =
    /// lazily skipped round, nothing emitted). Chunk bodies are
    /// byte-identical to the whole-message entries, so server-side
    /// reassembly is bit-exact with the sequential path and the bit
    /// accounting sums to the same totals.
    pub fn produce_chunked(
        &mut self,
        weights: &[Tensor],
        grads: &[Tensor],
        client_id: u32,
        round: u64,
        emit: &mut dyn FnMut(Vec<u8>),
    ) -> Option<u64> {
        let update = self.produce(weights, grads)?;
        for layer in 0..update.n_layers() {
            emit(crate::net::wire::Encoder::chunk(&update, layer, client_id, round));
        }
        Some(update.payload_bits())
    }

    /// Client-side pipeline state, in bytes (overhead experiment).
    pub fn mem_bytes(&self) -> usize {
        match &self.core {
            ClientCore::Sgd => 0,
            ClientCore::Lazy(c) => c.mem_bytes(),
            ClientCore::Pipe(core) => core.mem_bytes(),
        }
    }
}

#[derive(Debug)]
enum ServerCore {
    Sgd { shapes: Vec<Vec<usize>> },
    Lazy(SlaqServerState),
    Pipe { core: DecCore, shapes: Vec<Vec<usize>> },
}

/// The server half of a compiled pipeline, one instance per client:
/// wire update (or its absence) in, reconstructed gradients out.
#[derive(Debug)]
pub struct PipelineServer {
    label: String,
    core: ServerCore,
}

impl PipelineServer {
    /// The spec string this half was compiled from.
    pub fn label(&self) -> &str {
        &self.label
    }

    /// Absorb the client's update and return the gradient contribution:
    /// zeros for a missing upload, except the lazy pipeline which
    /// re-contributes the stale gradient (the SLAQ semantics).
    ///
    /// A **mismatched** update — wrong scheme, entry kinds or factor
    /// sizes — is discarded exactly like a lost frame (warn + no state
    /// change): the bytes are peer-controlled, so a misconfigured or
    /// hostile client must never panic the server mid-round.
    pub fn absorb(&mut self, update: Option<&ClientUpdate>) -> Vec<Tensor> {
        match &mut self.core {
            ServerCore::Sgd { shapes } => {
                match update {
                    Some(ClientUpdate::Sgd { grads })
                        if grads.len() == shapes.len()
                            && grads
                                .iter()
                                .zip(shapes.iter())
                                .all(|(g, s)| g.shape() == &s[..]) =>
                    {
                        return grads.clone();
                    }
                    Some(_) => log::warn!(
                        "identity pipeline discarding mismatched update (wrong scheme/shape)"
                    ),
                    None => {}
                }
                shapes.iter().map(|s| Tensor::zeros(s)).collect()
            }
            ServerCore::Lazy(state) => {
                match update {
                    Some(ClientUpdate::Slaq { msg }) if state.accepts(msg) => state.apply(msg),
                    Some(_) => log::warn!(
                        "lazy pipeline discarding mismatched update (wrong scheme/shape)"
                    ),
                    None => {}
                }
                state.latest().into_iter().cloned().collect()
            }
            ServerCore::Pipe { core, shapes } => {
                match update {
                    Some(ClientUpdate::Qrr { msgs }) if core.accepts(msgs) => {
                        return core.decode(msgs);
                    }
                    Some(_) => log::warn!(
                        "pipeline discarding mismatched update (wrong scheme/kind/shape)"
                    ),
                    None => {}
                }
                shapes.iter().map(|s| Tensor::zeros(s)).collect()
            }
        }
    }

    /// Server-side pipeline state for this client, in bytes.
    pub fn mem_bytes(&self) -> usize {
        match &self.core {
            ServerCore::Sgd { .. } => 0,
            ServerCore::Lazy(s) => s.mem_bytes(),
            ServerCore::Pipe { core, .. } => core.mem_bytes(),
        }
    }
}

// ------------------------------------------------------------ downlink

/// Server side of downlink compression: encodes the broadcast as a
/// compressed **parameter delta** against a shadow of what clients
/// currently hold, and advances the shadow by its own reconstruction —
/// so the next delta automatically re-sends this round's compression
/// error.
#[derive(Debug)]
pub struct DownlinkEncoder {
    /// the compiled spec, kept to re-mint fresh codec cores on resync
    pipe: CompressionPipeline,
    enc: EncCore,
    mirror: DecCore,
    shadow: Vec<Tensor>,
    /// dense broadcast counter stamped into each [`ServerUpdate`]
    seq: u64,
}

impl DownlinkEncoder {
    /// Build over a model's shapes; `init` is the initial parameter set
    /// both sides agree on out of band (the shadow's starting point).
    pub fn new(spec: &PipelineSpec, shapes: &[Vec<usize>], init: &[Tensor]) -> Result<Self> {
        spec.validate_downlink()?;
        let pipe = CompressionPipeline::compile(spec.clone(), shapes)?;
        Ok(DownlinkEncoder {
            enc: pipe.enc_core(),
            mirror: pipe.dec_core(),
            pipe,
            shadow: init.to_vec(),
            seq: 0,
        })
    }

    /// Encode `params` for broadcast at `round`. Advances the shadow to
    /// the clients' post-decode reconstruction and stamps the next
    /// sequence number (`round` is a free-form label and may jump; `seq`
    /// is the lock-step counter the decoder enforces).
    pub fn encode(&mut self, params: &[Tensor], round: u64) -> ServerUpdate {
        let delta: Vec<Tensor> = params
            .iter()
            .zip(self.shadow.iter())
            .map(|(p, s)| p.sub(s))
            .collect();
        let msgs = self.enc.encode(&delta);
        let rec = self.mirror.decode(&msgs);
        for (s, r) in self.shadow.iter_mut().zip(rec.iter()) {
            s.axpy(1.0, r);
        }
        let seq = self.seq;
        self.seq += 1;
        ServerUpdate { seq, round, msgs, snapshot: false }
    }

    /// Emit a resync **snapshot**: the shadow (≡ what an unfaulted
    /// decoder holds right now) as full-precision raw-dense entries,
    /// and reset this side's differential codec cores so the post-resync
    /// pair starts from a clean mirrored state. The frame stamps the
    /// *current* `seq` — the number the next delta will carry — so a
    /// decoder that [`DownlinkDecoder::apply_snapshot`]s it expects
    /// exactly that delta and every broadcast it missed is subsumed by
    /// the snapshot. Does not consume a sequence number.
    ///
    /// Resync coherence: the quantizer grids (and any residual state)
    /// on *both* halves must be rebuilt together, else the first
    /// post-resync delta decodes against stale grids. The encoder resets
    /// `enc` + `mirror` here; the decoder resets its mirror inside
    /// `apply_snapshot`.
    pub fn snapshot(&mut self, round: u64) -> ServerUpdate {
        self.enc = self.pipe.enc_core();
        self.mirror = self.pipe.dec_core();
        let msgs = self
            .shadow
            .iter()
            .map(|t| ParamMsg::RawDense { t: t.clone() })
            .collect();
        ServerUpdate { seq: self.seq, round, msgs, snapshot: true }
    }

    /// The server's copy of the clients' current model reconstruction.
    pub fn shadow(&self) -> &[Tensor] {
        &self.shadow
    }

    /// Downlink codec state held server-side, in bytes.
    pub fn mem_bytes(&self) -> usize {
        self.enc.mem_bytes()
            + self.mirror.mem_bytes()
            + self.shadow.iter().map(|t| t.len() * 4).sum::<usize>()
    }
}

/// Client side of downlink compression: decodes each broadcast delta
/// and locally reconstructs the model. Must stay in lock-step with the
/// server's [`DownlinkEncoder`] (same spec, same `init`).
#[derive(Debug)]
pub struct DownlinkDecoder {
    /// the compiled spec, kept to re-mint a fresh codec core on resync
    pipe: CompressionPipeline,
    dec: DecCore,
    params: Vec<Tensor>,
    /// sequence number the next broadcast must carry
    next_seq: u64,
}

impl DownlinkDecoder {
    /// Build the mirror decoder; see [`DownlinkEncoder::new`].
    pub fn new(spec: &PipelineSpec, shapes: &[Vec<usize>], init: &[Tensor]) -> Result<Self> {
        spec.validate_downlink()?;
        let pipe = CompressionPipeline::compile(spec.clone(), shapes)?;
        Ok(DownlinkDecoder { dec: pipe.dec_core(), pipe, params: init.to_vec(), next_seq: 0 })
    }

    /// Apply one broadcast: decode the delta and advance the local model.
    ///
    /// The differential codec state must apply every broadcast exactly
    /// once, in order, so anything but the next sequence number —
    /// a replay, a reordering, or a **gap** from a lost broadcast — is
    /// rejected without touching the state (a gap would silently
    /// desynchronize the mirrored quantizer grids forever). Mismatched
    /// message kinds/shapes are rejected the same way.
    pub fn apply(&mut self, update: &ServerUpdate) -> Result<&[Tensor]> {
        // a snapshot is full state, not a delta — applying one here
        // (the raw pipeline would happily "add" it) must be impossible
        ensure!(
            !update.snapshot,
            "snapshot frame on the delta path: use apply_snapshot"
        );
        ensure!(
            update.seq == self.next_seq,
            "broadcast out of sequence: got seq {}, expected {} \
             (a broadcast was lost, replayed or reordered)",
            update.seq,
            self.next_seq
        );
        ensure!(
            self.dec.accepts(&update.msgs),
            "broadcast does not match the downlink pipeline (kind/shape mismatch)"
        );
        let delta = self.dec.decode(&update.msgs);
        for (p, d) in self.params.iter_mut().zip(delta.iter()) {
            p.axpy(1.0, d);
        }
        self.next_seq += 1;
        Ok(&self.params)
    }

    /// Whether `update` reveals that this decoder missed one or more
    /// broadcasts — i.e. [`apply`](Self::apply) would reject it with a
    /// sequence **gap** (or a reorder/replay) — and the session should
    /// fetch a snapshot instead of feeding the delta in.
    pub fn needs_resync(&self, update: &ServerUpdate) -> bool {
        !update.snapshot && update.seq != self.next_seq
    }

    /// Re-prime from a resync snapshot (see
    /// [`DownlinkEncoder::snapshot`]): replace the local model with the
    /// snapshot state, rebuild the differential codec core, and expect
    /// the snapshot's `seq` next — every broadcast missed in the gap is
    /// subsumed. Snapshot bytes cross the same hostile wire as deltas,
    /// so a malformed one (wrong kind, wrong tensor count/shape) is a
    /// typed error that leaves the decoder untouched.
    // qrr-audit: no-panic
    pub fn apply_snapshot(&mut self, update: &ServerUpdate) -> Result<&[Tensor]> {
        ensure!(update.snapshot, "delta frame on the resync path: use apply");
        ensure!(
            update.msgs.len() == self.params.len(),
            "snapshot carries {} tensors, model has {}",
            update.msgs.len(),
            self.params.len()
        );
        let mut fresh: Vec<Tensor> = Vec::with_capacity(update.msgs.len());
        for (msg, cur) in update.msgs.iter().zip(self.params.iter()) {
            match msg {
                ParamMsg::RawDense { t } if t.shape() == cur.shape() => fresh.push(t.clone()),
                _ => bail!("snapshot entry does not match the model (kind/shape mismatch)"),
            }
        }
        self.params = fresh;
        self.dec = self.pipe.dec_core();
        self.next_seq = update.seq;
        Ok(&self.params)
    }
    // qrr-audit: end

    /// The locally reconstructed model parameters.
    pub fn params(&self) -> &[Tensor] {
        &self.params
    }

    /// Downlink codec state held client-side, in bytes.
    pub fn mem_bytes(&self) -> usize {
        self.dec.mem_bytes() + self.params.iter().map(|t| t.len() * 4).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn mlp_shapes() -> Vec<Vec<usize>> {
        vec![vec![20, 30], vec![20], vec![4, 3, 3, 3]]
    }

    // ------------------------------------------------ grammar round-trips

    #[test]
    fn every_preset_parses_and_round_trips() {
        for p in presets() {
            let spec = PipelineSpec::parse(p.name).unwrap();
            assert_eq!(spec.format(), p.spec, "preset {}", p.name);
            let back = PipelineSpec::parse(&spec.format()).unwrap();
            assert_eq!(back, spec, "preset {} spec round-trip", p.name);
        }
    }

    #[test]
    fn preset_arguments_resolve() {
        assert_eq!(PipelineSpec::parse("qrr(p=0.2)").unwrap(), PipelineSpec::qrr(0.2, 8));
        assert_eq!(
            PipelineSpec::parse("qrr(p=0.1,beta=4)").unwrap(),
            PipelineSpec::qrr(0.1, 4)
        );
        assert_eq!(PipelineSpec::parse("slaq(beta=12)").unwrap(), PipelineSpec::slaq(12));
        assert_eq!(
            PipelineSpec::parse("ef-qrr(p=0.05)").unwrap(),
            PipelineSpec::qrr_ef(0.05, 8)
        );
        assert_eq!(PipelineSpec::parse("sgd").unwrap(), PipelineSpec::sgd());
    }

    #[test]
    fn every_stage_combination_round_trips() {
        let combos = [
            "identity",
            "laq(beta=8)",
            "laq(beta=8)+lazy",
            "svd(p=0.1)",
            "tucker(p=0.25)",
            "svd(p=0.1)+tucker(p=0.25)",
            "svd(p=0.1)+laq(beta=8)",
            "tucker(p=0.25)+laq(beta=4)",
            "svd(p=0.1)+tucker(p=0.25)+laq(beta=8)",
            "laq(beta=8)+ef",
            "svd(p=0.1)+laq(beta=8)+ef",
            "svd(p=0.1)+tucker(p=0.25)+laq(beta=8)+ef",
        ];
        for s in combos {
            let spec = PipelineSpec::parse(s).unwrap();
            assert_eq!(spec.format(), s, "canonical form drifted for {s:?}");
            assert_eq!(PipelineSpec::parse(&spec.format()).unwrap(), spec);
        }
    }

    #[test]
    fn malformed_specs_rejected() {
        for bad in [
            "",
            "rle(p=0.1)",                      // unknown stage
            "svd",                             // missing p
            "svd(p=0)",                        // p out of range
            "svd(p=1.5)",                      // p out of range
            "svd(p=abc)",                      // unparseable p
            "svd(q=0.1)",                      // unknown argument
            "laq",                             // missing beta
            "laq(beta=0)",                     // beta out of range
            "laq(beta=17)",                    // beta out of range
            "svd(p=0.1)+laq(beta=8)+",         // trailing +
            "+svd(p=0.1)",                     // leading +
            "svd(p=0.1)++laq(beta=8)",         // doubled +
            "svd(p=0.1",                       // unclosed paren
            "ef",                              // ef without laq
            "svd(p=0.1)+ef",                   // ef without laq
            "lazy",                            // lazy without laq
            "svd(p=0.1)+laq(beta=8)+lazy",     // lazy with reducers
            "laq(beta=8)+ef+lazy",             // lazy with ef
            "identity+laq(beta=8)",            // identity not alone
            "svd(p=0.1)+svd(p=0.2)",           // duplicate stage
            "laq(beta=8)+laq(beta=4)",         // duplicate quantizer
            "sgd(p=0.1)",                      // preset with bogus args
            "slaq(p=0.2)",                     // preset arg it doesn't take
            "svd(p=0.1,beta=4)",               // beta on a reducer stage
            "laq(beta=8,p=0.5)",               // p on the quantizer stage
        ] {
            assert!(PipelineSpec::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn registry_lists_presets_and_stages() {
        let ps = presets();
        assert_eq!(ps.len(), 4);
        for p in &ps {
            // every listed preset must resolve through the parser
            PipelineSpec::parse(p.name).unwrap();
            PipelineSpec::parse(&p.spec).unwrap();
        }
        assert!(stages().len() >= 6);
    }

    // ------------------------------------------------- compiled behavior

    #[test]
    fn custom_dyn_stage_compiles_through_the_trait_seam() {
        // a third-party RankReducer with its own policy: SVD only for
        // wide matrices, everything else left dense
        struct WideOnly {
            p: f64,
        }
        impl RankReducer for WideOnly {
            fn label(&self) -> String {
                format!("wide-only(p={})", self.p)
            }
            fn plan(&self, shape: &[usize]) -> Option<ReducePlan> {
                (shape.len() == 2 && shape[1] > shape[0])
                    .then(|| ReducePlan::Svd { nu: svd_rank(shape[0], shape[1], self.p) })
            }
        }
        let shapes = vec![vec![20usize, 30], vec![30, 20], vec![20]];
        let stages: Vec<Box<dyn RankReducer>> = vec![Box::new(WideOnly { p: 0.2 })];
        let pipe = CompressionPipeline::compile_with(
            &stages,
            Some(QuantizerSpec::Laq { beta: 8 }),
            Feedback::None,
            &shapes,
        )
        .unwrap();
        assert!(matches!(pipe.plans()[0], ReducePlan::Svd { .. }), "wide matrix claimed");
        assert!(matches!(pipe.plans()[1], ReducePlan::Dense), "tall matrix left dense");
        assert!(matches!(pipe.plans()[2], ReducePlan::Dense));
        assert_eq!(pipe.label(), "wide-only(p=0.2)+laq(beta=8)");

        // the mirrored halves work end to end like any spec pipeline
        let mut rng = Rng::new(910);
        let grads: Vec<Tensor> = shapes.iter().map(|sh| Tensor::randn(sh, &mut rng)).collect();
        let mut c = pipe.client(&BuildCtx { alpha: 0.01, clients: 2 });
        let mut s = pipe.server();
        let up = c.produce(&[], &grads).unwrap();
        let back = s.absorb(Some(&up));
        assert_eq!(back.len(), 3);
        // the dense-kept tall matrix is quantize-only: near-exact
        assert!(grads[1].rel_err(&back[1]) < 0.01);
    }

    #[test]
    fn boxed_spec_stages_match_the_spec_path() {
        // ReducerSpec::stage()/QuantizerSpec::stage() vend the same
        // behavior the enum path compiles
        let shapes = mlp_shapes();
        let spec = PipelineSpec::qrr(0.3, 8);
        let stages: Vec<Box<dyn RankReducer>> = spec.reducers.iter().map(|r| r.stage()).collect();
        let by_stages = CompressionPipeline::compile_with(
            &stages,
            spec.quantizer,
            spec.feedback,
            &shapes,
        )
        .unwrap();
        let by_spec = CompressionPipeline::compile(spec, &shapes).unwrap();
        assert_eq!(by_stages.plans(), by_spec.plans());
        assert_eq!(by_stages.label(), by_spec.label());

        // the Identity stage and the Quantizer::beta contract
        assert_eq!(RankReducer::plan(&Identity, &[4, 5]), Some(ReducePlan::Dense));
        assert_eq!(Quantizer::beta(&Identity), None);
        assert_eq!(QuantizerSpec::Laq { beta: 8 }.stage().beta(), Some(8));
        // ef with the identity quantizer is invalid through this entry too
        assert!(CompressionPipeline::compile_with(
            &stages,
            None,
            Feedback::ErrorFeedback,
            &shapes
        )
        .is_err());
    }

    #[test]
    fn plans_assign_reducers_by_shape() {
        let spec = PipelineSpec::qrr(0.5, 8);
        let pipe = CompressionPipeline::compile(spec, &mlp_shapes()).unwrap();
        assert!(matches!(pipe.plans()[0], ReducePlan::Svd { .. }));
        assert!(matches!(pipe.plans()[1], ReducePlan::Dense));
        assert!(matches!(pipe.plans()[2], ReducePlan::Tucker { .. }));

        // svd-only pipeline leaves the conv kernel dense
        let spec = PipelineSpec::parse("svd(p=0.5)+laq(beta=8)").unwrap();
        let pipe = CompressionPipeline::compile(spec, &mlp_shapes()).unwrap();
        assert!(matches!(pipe.plans()[2], ReducePlan::Dense));
    }

    #[test]
    fn identity_pipeline_is_lossless() {
        let shapes = mlp_shapes();
        let spec = PipelineSpec::parse("identity").unwrap();
        let pipe = CompressionPipeline::compile(spec, &shapes).unwrap();
        let mut c = pipe.client(&BuildCtx { alpha: 0.01, clients: 2 });
        let mut s = pipe.server();
        let mut rng = Rng::new(900);
        let grads: Vec<Tensor> = shapes.iter().map(|sh| Tensor::randn(sh, &mut rng)).collect();
        let up = c.produce(&[], &grads).unwrap();
        let back = s.absorb(Some(&up));
        for (a, b) in grads.iter().zip(back.iter()) {
            assert_eq!(a, b);
        }
        assert_eq!(c.mem_bytes(), 0);
        assert_eq!(s.mem_bytes(), 0);
    }

    #[test]
    fn produce_chunked_streams_bit_identical_frames() {
        use crate::net::wire::{Decoder, Encoder};

        let shapes = mlp_shapes();
        let spec = PipelineSpec::qrr(0.3, 8);
        let pipe = CompressionPipeline::compile(spec, &shapes).unwrap();
        let mut rng = Rng::new(911);
        let grads: Vec<Tensor> = shapes.iter().map(|sh| Tensor::randn(sh, &mut rng)).collect();

        // the sequential oracle
        let mut seq = pipe.client(&BuildCtx { alpha: 0.01, clients: 2 });
        let whole = seq.produce(&[], &grads).unwrap();

        // the streamed path emits one frame per layer as it serializes
        let mut streamed = pipe.client(&BuildCtx { alpha: 0.01, clients: 2 });
        let mut frames = Vec::new();
        let bits = streamed
            .produce_chunked(&[], &grads, 7, 3, &mut |f| frames.push(f))
            .unwrap();
        assert_eq!(bits, whole.payload_bits(), "streamed bit accounting drifted");
        assert_eq!(frames.len(), whole.n_layers());

        let mut bodies = Vec::new();
        let mut scheme = 0;
        for (layer, f) in frames.iter().enumerate() {
            let (h, body) = Decoder::decode_chunk(f).unwrap();
            assert_eq!((h.client_id, h.round), (7, 3));
            assert_eq!(h.layer as usize, layer);
            assert_eq!(h.last, layer + 1 == frames.len());
            scheme = h.scheme;
            bodies.push(body);
        }
        let back = Decoder::assemble_update(scheme, bodies).unwrap();
        assert_eq!(
            Encoder::new(&back, 7, 3),
            Encoder::new(&whole, 7, 3),
            "reassembled update is not bit-identical"
        );
    }

    #[test]
    fn raw_svd_pipeline_reconstructs_without_quantization() {
        // svd(p) with the identity quantizer: truncation error only
        let shapes = vec![vec![30usize, 40]];
        let mut rng = Rng::new(901);
        let u = Tensor::randn(&[30, 3], &mut rng);
        let v = Tensor::randn(&[3, 40], &mut rng);
        let g = crate::linalg::matmul(&u, &v); // rank 3
        let spec = PipelineSpec::parse("svd(p=0.2)").unwrap(); // rank 6 >= 3
        let pipe = CompressionPipeline::compile(spec, &shapes).unwrap();
        let mut c = pipe.client(&BuildCtx { alpha: 0.01, clients: 2 });
        let mut s = pipe.server();
        let up = c.produce(&[], std::slice::from_ref(&g)).unwrap();
        let back = s.absorb(Some(&up));
        assert!(g.rel_err(&back[0]) < 1e-4, "err {}", g.rel_err(&back[0]));
        // and the payload is smaller than dense
        assert!(up.payload_bits() < 32 * g.len() as u64);
    }

    #[test]
    fn laq_only_pipeline_quantizes_every_parameter() {
        let shapes = mlp_shapes();
        let spec = PipelineSpec::parse("laq(beta=12)").unwrap();
        let pipe = CompressionPipeline::compile(spec, &shapes).unwrap();
        let mut c = pipe.client(&BuildCtx { alpha: 0.01, clients: 2 });
        let mut s = pipe.server();
        let mut rng = Rng::new(902);
        let grads: Vec<Tensor> = shapes.iter().map(|sh| Tensor::randn(sh, &mut rng)).collect();
        let up = c.produce(&[], &grads).unwrap();
        let back = s.absorb(Some(&up));
        for (a, b) in grads.iter().zip(back.iter()) {
            assert!(a.rel_err(b) < 0.01, "err {}", a.rel_err(b));
        }
    }

    #[test]
    fn mirrored_halves_stay_in_sync_over_rounds() {
        let shapes = mlp_shapes();
        for spec_str in ["qrr(p=0.2)", "svd(p=0.3)+laq(beta=8)", "laq(beta=8)+ef"] {
            let spec = PipelineSpec::parse(spec_str).unwrap();
            let pipe = CompressionPipeline::compile(spec, &shapes).unwrap();
            let mut c = pipe.client(&BuildCtx { alpha: 0.01, clients: 2 });
            let mut s = pipe.server();
            let mut rng = Rng::new(903);
            let mut errs = Vec::new();
            let g0: Vec<Tensor> = shapes.iter().map(|sh| Tensor::randn(sh, &mut rng)).collect();
            for _ in 0..6 {
                let up = c.produce(&[], &g0).unwrap();
                let back = s.absorb(Some(&up));
                errs.push(g0[0].rel_err(&back[0]));
            }
            // differential/EF state refines on a repeated gradient
            assert!(
                errs.last().unwrap() <= &(errs[0] + 1e-6),
                "{spec_str}: no refinement {errs:?}"
            );
        }
    }

    #[test]
    fn missing_upload_contributes_zeros_except_lazy() {
        let shapes = mlp_shapes();
        let pipe = CompressionPipeline::compile(PipelineSpec::qrr(0.3, 8), &shapes).unwrap();
        let mut s = pipe.server();
        for t in s.absorb(None) {
            assert_eq!(t.fro_norm(), 0.0);
        }
    }

    #[test]
    fn mismatched_updates_are_discarded_not_panics() {
        // the wire bytes are peer-controlled: every wire-decodable update
        // of the wrong scheme, kind or shape must be treated like a lost
        // frame, never a server panic
        let shapes = mlp_shapes();
        let mut rng = Rng::new(909);
        let grads: Vec<Tensor> = shapes.iter().map(|sh| Tensor::randn(sh, &mut rng)).collect();

        // a raw (identity-quantizer) update aimed at a quantized server
        let raw_pipe =
            CompressionPipeline::compile(PipelineSpec::parse("svd(p=0.2)").unwrap(), &shapes)
                .unwrap();
        let raw_up = raw_pipe
            .client(&BuildCtx { alpha: 0.01, clients: 2 })
            .produce(&[], &grads)
            .unwrap();
        let qrr_pipe = CompressionPipeline::compile(PipelineSpec::qrr(0.2, 8), &shapes).unwrap();
        let mut server = qrr_pipe.server();
        for t in server.absorb(Some(&raw_up)) {
            assert_eq!(t.fro_norm(), 0.0, "mismatched update must contribute zeros");
        }

        // wrong scheme tags against every server core
        let sgd_up = ClientUpdate::Sgd { grads: grads.clone() };
        for t in server.absorb(Some(&sgd_up)) {
            assert_eq!(t.fro_norm(), 0.0);
        }
        let mut identity_server =
            CompressionPipeline::compile(PipelineSpec::sgd(), &shapes).unwrap().server();
        let qrr_up = qrr_pipe
            .client(&BuildCtx { alpha: 0.01, clients: 2 })
            .produce(&[], &grads)
            .unwrap();
        for t in identity_server.absorb(Some(&qrr_up)) {
            assert_eq!(t.fro_norm(), 0.0);
        }
        let mut lazy_server =
            CompressionPipeline::compile(PipelineSpec::slaq(8), &shapes).unwrap().server();
        // SLAQ absence semantics: stale state (zeros initially), no panic
        let _ = lazy_server.absorb(Some(&qrr_up));

        // wrong shapes inside the right scheme: an Sgd update whose
        // tensors do not match the model
        let bogus = ClientUpdate::Sgd { grads: vec![Tensor::zeros(&[3])] };
        for t in identity_server.absorb(Some(&bogus)) {
            assert_eq!(t.fro_norm(), 0.0);
        }

        // a wire-craftable payload with the right lengths but a beta
        // outside the quantizer grid (the decoder accepts any beta
        // byte) must be discarded, not panic in dequantize
        let laq_pipe =
            CompressionPipeline::compile(PipelineSpec::parse("laq(beta=8)").unwrap(), &shapes)
                .unwrap();
        let mut laq_server = laq_pipe.server();
        let hostile = ClientUpdate::Qrr {
            msgs: shapes
                .iter()
                .map(|sh| {
                    let len = sh.iter().product::<usize>();
                    ParamMsg::Dense {
                        q: crate::quant::Quantized {
                            radius: 1.0,
                            beta: 42,
                            len,
                            packed: vec![0u8; crate::quant::packed_len_bytes(len, 42)],
                        },
                    }
                })
                .collect(),
        };
        for t in laq_server.absorb(Some(&hostile)) {
            assert_eq!(t.fro_norm(), 0.0, "hostile beta must be discarded");
        }
        // non-finite radius likewise
        let nan_radius = ClientUpdate::Qrr {
            msgs: shapes
                .iter()
                .map(|sh| {
                    let len = sh.iter().product::<usize>();
                    ParamMsg::Dense {
                        q: crate::quant::Quantized {
                            radius: f32::NAN,
                            beta: 8,
                            len,
                            packed: vec![0u8; crate::quant::packed_len_bytes(len, 8)],
                        },
                    }
                })
                .collect(),
        };
        for t in laq_server.absorb(Some(&nan_radius)) {
            assert_eq!(t.fro_norm(), 0.0, "non-finite radius must be discarded");
        }
    }

    // --------------------------------------------------------- downlink

    #[test]
    fn downlink_rejects_lazy_and_ef() {
        let shapes = mlp_shapes();
        let mut rng = Rng::new(904);
        let init: Vec<Tensor> = shapes.iter().map(|sh| Tensor::randn(sh, &mut rng)).collect();
        for bad in ["laq(beta=8)+lazy", "svd(p=0.1)+laq(beta=8)+ef"] {
            let spec = PipelineSpec::parse(bad).unwrap();
            assert!(DownlinkEncoder::new(&spec, &shapes, &init).is_err(), "{bad}");
            assert!(DownlinkDecoder::new(&spec, &shapes, &init).is_err(), "{bad}");
        }
    }

    #[test]
    fn downlink_shadow_mirrors_client_reconstruction() {
        let shapes = mlp_shapes();
        let mut rng = Rng::new(905);
        let init: Vec<Tensor> = shapes.iter().map(|sh| Tensor::randn(sh, &mut rng)).collect();
        let spec = PipelineSpec::parse("svd(p=0.5)+laq(beta=8)").unwrap();
        let mut enc = DownlinkEncoder::new(&spec, &shapes, &init).unwrap();
        let mut dec = DownlinkDecoder::new(&spec, &shapes, &init).unwrap();

        let mut params = init.clone();
        for round in 0..8u64 {
            // simulate a descent step
            for p in params.iter_mut() {
                p.axpy(0.05, &Tensor::randn(p.shape(), &mut rng));
            }
            let upd = enc.encode(&params, round);
            let rec = dec.apply(&upd).unwrap();
            // the server's shadow and the client's model are the same state
            for (a, b) in enc.shadow().iter().zip(rec.iter()) {
                assert!(a.rel_err(b) < 1e-6, "shadow diverged from client");
            }
        }
        // delta feedback: the reconstruction tracks the true parameters
        for (p, r) in params.iter().zip(dec.params().iter()) {
            assert!(
                p.rel_err(r) < 0.6,
                "reconstruction lost the signal: {}",
                p.rel_err(r)
            );
        }
    }

    #[test]
    fn identity_downlink_is_lossless_and_fullprice() {
        let shapes = vec![vec![6usize, 4], vec![6]];
        let mut rng = Rng::new(906);
        let init: Vec<Tensor> = shapes.iter().map(|sh| Tensor::randn(sh, &mut rng)).collect();
        let spec = PipelineSpec::sgd();
        let mut enc = DownlinkEncoder::new(&spec, &shapes, &init).unwrap();
        let mut dec = DownlinkDecoder::new(&spec, &shapes, &init).unwrap();
        let mut params = init.clone();
        params[0].axpy(1.0, &Tensor::randn(&[6, 4], &mut rng));
        let upd = enc.encode(&params, 0);
        assert_eq!(upd.payload_bits(), 32 * (6 * 4 + 6));
        let rec = dec.apply(&upd).unwrap();
        for (p, r) in params.iter().zip(rec.iter()) {
            assert!(p.rel_err(r) < 1e-6);
        }
    }

    #[test]
    fn downlink_decoder_rejects_replays_reorders_and_gaps() {
        let shapes = vec![vec![5usize, 4]];
        let mut rng = Rng::new(908);
        let init: Vec<Tensor> = shapes.iter().map(|sh| Tensor::randn(sh, &mut rng)).collect();
        let spec = PipelineSpec::parse("laq(beta=8)").unwrap();
        let mut enc = DownlinkEncoder::new(&spec, &shapes, &init).unwrap();
        let mut dec = DownlinkDecoder::new(&spec, &shapes, &init).unwrap();
        let mut params = init.clone();
        let mut next = |enc: &mut DownlinkEncoder, params: &mut Vec<Tensor>, rng: &mut Rng| {
            params[0].axpy(0.2, &Tensor::randn(&[5, 4], rng));
            enc.encode(params, 0) // round label free-form; seq is what counts
        };
        let upd0 = next(&mut enc, &mut params, &mut rng);
        let upd1 = next(&mut enc, &mut params, &mut rng);
        let upd2 = next(&mut enc, &mut params, &mut rng);
        assert_eq!((upd0.seq, upd1.seq, upd2.seq), (0, 1, 2));

        // reordered: seq 1 before seq 0
        assert!(dec.apply(&upd1).is_err());
        let snapshot = dec.apply(&upd0).unwrap().to_vec();
        // replayed: seq 0 twice
        assert!(dec.apply(&upd0).is_err());
        // gap: seq 2 while 1 is missing — a lost broadcast would silently
        // desynchronize the differential grids, so it must be an error
        assert!(dec.apply(&upd2).is_err());
        for (a, b) in snapshot.iter().zip(dec.params().iter()) {
            assert_eq!(a, b, "rejected broadcast mutated the model");
        }
        // in-order delivery proceeds
        assert!(dec.apply(&upd1).is_ok());
        assert!(dec.apply(&upd2).is_ok());
        // and a mismatched payload is rejected even at the right seq
        let mut bad = next(&mut enc, &mut params, &mut rng);
        bad.msgs.push(ParamMsg::RawDense { t: Tensor::zeros(&[5, 4]) });
        assert!(dec.apply(&bad).is_err());
    }

    #[test]
    fn compressed_downlink_ships_fewer_bits_than_identity() {
        let shapes = vec![vec![50usize, 80], vec![50]];
        let mut rng = Rng::new(907);
        let init: Vec<Tensor> = shapes.iter().map(|sh| Tensor::randn(sh, &mut rng)).collect();
        let mut params = init.clone();
        params[0].axpy(0.1, &Tensor::randn(&[50, 80], &mut rng));

        let dense_bits = {
            let mut enc = DownlinkEncoder::new(&PipelineSpec::sgd(), &shapes, &init).unwrap();
            enc.encode(&params, 0).payload_bits()
        };
        let spec = PipelineSpec::parse("svd(p=0.1)+laq(beta=8)").unwrap();
        let mut enc = DownlinkEncoder::new(&spec, &shapes, &init).unwrap();
        let compressed_bits = enc.encode(&params, 0).payload_bits();
        assert!(
            compressed_bits * 2 < dense_bits,
            "compressed {compressed_bits} vs dense {dense_bits}"
        );
    }

    // --------------------------------------------- snapshot resync

    /// Resync must restore *exactly* the state an unfaulted decoder
    /// holds — bit-for-bit, not merely close.
    fn assert_bit_identical(a: &[Tensor], b: &[Tensor]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.shape(), y.shape());
            for (va, vb) in x.data().iter().zip(y.data().iter()) {
                assert_eq!(va.to_bits(), vb.to_bits(), "state differs in bits");
            }
        }
    }

    #[test]
    fn snapshot_resyncs_a_gap_decoder_bit_identically() {
        use crate::net::wire::{Decoder, Encoder};

        let shapes = mlp_shapes();
        let mut rng = Rng::new(909);
        let init: Vec<Tensor> = shapes.iter().map(|sh| Tensor::randn(sh, &mut rng)).collect();
        let spec = PipelineSpec::parse("qrr").unwrap();
        let mut enc = DownlinkEncoder::new(&spec, &shapes, &init).unwrap();
        // the unfaulted replay this PR's acceptance bar compares against
        let mut healthy = DownlinkDecoder::new(&spec, &shapes, &init).unwrap();
        let mut faulty = DownlinkDecoder::new(&spec, &shapes, &init).unwrap();

        let mut params = init.clone();
        let step = |params: &mut Vec<Tensor>, rng: &mut Rng| {
            for p in params.iter_mut() {
                p.axpy(0.05, &Tensor::randn(p.shape(), rng));
            }
        };

        // round 0 reaches both decoders
        step(&mut params, &mut rng);
        let upd0 = enc.encode(&params, 0);
        healthy.apply(&upd0).unwrap();
        faulty.apply(&upd0).unwrap();
        // round 1's broadcast is lost on the faulty link
        step(&mut params, &mut rng);
        let upd1 = enc.encode(&params, 1);
        healthy.apply(&upd1).unwrap();
        // round 2 reveals the gap
        step(&mut params, &mut rng);
        let upd2 = enc.encode(&params, 2);
        healthy.apply(&upd2).unwrap();
        assert!(faulty.needs_resync(&upd2));
        assert!(faulty.apply(&upd2).is_err());

        // resync: the snapshot crosses the real wire like any broadcast
        let snap = enc.snapshot(2);
        assert!(!faulty.needs_resync(&snap), "a snapshot never demands another resync");
        let snap = Decoder::decode_server(&Encoder::server(&snap)).unwrap();
        // full-precision full state: 32 bits per model element
        assert_eq!(snap.payload_bits(), 32 * (600 + 20 + 108) as u64);
        faulty.apply_snapshot(&snap).unwrap();

        // post-resync state is bit-identical to the unfaulted replay
        // (both equal the encoder's shadow by the lock-step invariant)
        assert_bit_identical(faulty.params(), healthy.params());
        assert_bit_identical(faulty.params(), enc.shadow());

        // the pair is coherent again: subsequent deltas apply cleanly
        // and keep tracking the shadow exactly
        for round in 3..6u64 {
            step(&mut params, &mut rng);
            let upd = enc.encode(&params, round);
            faulty.apply(&upd).unwrap();
            assert_bit_identical(faulty.params(), enc.shadow());
        }
    }

    #[test]
    fn snapshot_frames_never_cross_the_delta_path() {
        // raw (identity) downlink: apply() of a snapshot would otherwise
        // silently *add* full state to the model
        let shapes = vec![vec![6usize, 4], vec![6]];
        let mut rng = Rng::new(910);
        let init: Vec<Tensor> = shapes.iter().map(|sh| Tensor::randn(sh, &mut rng)).collect();
        let spec = PipelineSpec::sgd();
        let mut enc = DownlinkEncoder::new(&spec, &shapes, &init).unwrap();
        let mut dec = DownlinkDecoder::new(&spec, &shapes, &init).unwrap();

        let mut params = init.clone();
        params[0].axpy(0.3, &Tensor::randn(&[6, 4], &mut rng));
        let upd0 = enc.encode(&params, 0);
        dec.apply(&upd0).unwrap();

        let snap = enc.snapshot(0);
        assert!(dec.apply(&snap).is_err(), "snapshot must not apply as a delta");
        assert!(dec.apply_snapshot(&upd0).is_err(), "delta must not apply as a snapshot");

        // malformed snapshots are typed errors that leave state intact
        let before = dec.params().to_vec();
        let mut bad = snap.clone();
        bad.msgs.pop();
        assert!(dec.apply_snapshot(&bad).is_err(), "tensor count mismatch must fail");
        let mut bad = snap.clone();
        bad.msgs[0] = ParamMsg::RawDense { t: Tensor::zeros(&[3]) };
        assert!(dec.apply_snapshot(&bad).is_err(), "shape mismatch must fail");
        for (a, b) in before.iter().zip(dec.params().iter()) {
            assert_eq!(a, b, "rejected snapshot mutated the model");
        }

        // the well-formed one applies and restores lock-step
        dec.apply_snapshot(&snap).unwrap();
        assert_bit_identical(dec.params(), enc.shadow());
        params[0].axpy(0.3, &Tensor::randn(&[6, 4], &mut rng));
        let upd1 = enc.encode(&params, 1);
        dec.apply(&upd1).unwrap();
    }
}
