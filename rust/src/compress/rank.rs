//! Rank-selection rules and communication-efficiency inequalities.

/// SVD rank ν = ⌈p·min(m, n)⌉, clamped to [1, min(m,n)] (paper eq. (22)).
pub fn svd_rank(m: usize, n: usize, p: f64) -> usize {
    let r = (p * m.min(n) as f64).ceil() as usize;
    r.clamp(1, m.min(n))
}

/// Tucker per-mode ranks rᵢ = ⌈p·Iᵢ⌉, clamped to [1, Iᵢ] (paper eq. (23)).
pub fn tucker_ranks(dims: &[usize], p: f64) -> Vec<usize> {
    dims.iter()
        .map(|&d| ((p * d as f64).ceil() as usize).clamp(1, d))
        .collect()
}

/// Element count of the truncated-SVD factors (U, diag Σ, V).
pub fn svd_factor_elems(m: usize, n: usize, nu: usize) -> usize {
    m * nu + nu + n * nu
}

/// Paper inequality (8): is the truncated SVD smaller than the raw matrix?
pub fn svd_is_smaller(m: usize, n: usize, nu: usize) -> bool {
    svd_factor_elems(m, n, nu) < m * n
}

/// Element count of the Tucker factors (core + Fᵢ).
pub fn tucker_factor_elems(dims: &[usize], ranks: &[usize]) -> usize {
    assert_eq!(dims.len(), ranks.len());
    let core: usize = ranks.iter().product();
    let factors: usize = dims.iter().zip(ranks.iter()).map(|(d, r)| d * r).sum();
    core + factors
}

/// Paper inequality (11): is the Tucker form smaller than the raw tensor?
pub fn tucker_is_smaller(dims: &[usize], ranks: &[usize]) -> bool {
    tucker_factor_elems(dims, ranks) < dims.iter().product()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn svd_rank_rule() {
        // paper MLP layer: 200x784, p=0.1 -> ceil(0.1*200)=20
        assert_eq!(svd_rank(200, 784, 0.1), 20);
        assert_eq!(svd_rank(200, 784, 0.3), 60);
        assert_eq!(svd_rank(10, 200, 0.1), 1);
        // clamped at min dim
        assert_eq!(svd_rank(4, 6, 2.0), 4);
        // never zero
        assert_eq!(svd_rank(100, 100, 0.0), 1);
    }

    #[test]
    fn tucker_rank_rule() {
        // paper conv layer: 32x16x3x3, p=0.3
        assert_eq!(tucker_ranks(&[32, 16, 3, 3], 0.3), vec![10, 5, 1, 1]);
        assert_eq!(tucker_ranks(&[32, 16, 3, 3], 0.1), vec![4, 2, 1, 1]);
    }

    #[test]
    fn inequality_8_for_paper_shapes() {
        // 200x784 with p=0.3 (nu=60): 200*60+60+784*60 = 59100 < 156800
        assert!(svd_is_smaller(200, 784, 60));
        // full rank never smaller
        assert!(!svd_is_smaller(200, 784, 200));
        // tiny output layer 10x200, nu=3: 10*3+3+200*3 = 633 < 2000
        assert!(svd_is_smaller(10, 200, 3));
    }

    #[test]
    fn inequality_11_for_paper_shapes() {
        let dims = [32usize, 16, 3, 3];
        let r = tucker_ranks(&dims, 0.3);
        // 10*5*1*1 + 32*10 + 16*5 + 3 + 3 = 50+320+80+6 = 456 < 4608
        assert!(tucker_is_smaller(&dims, &r));
        assert!(!tucker_is_smaller(&dims, &[32, 16, 3, 3]));
    }

    #[test]
    fn factor_elem_counts() {
        assert_eq!(svd_factor_elems(4, 6, 2), 8 + 2 + 12);
        assert_eq!(tucker_factor_elems(&[4, 4], &[2, 2]), 4 + 8 + 8);
    }
}
