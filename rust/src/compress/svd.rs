//! Truncated-SVD compression of matrix gradients (paper eq. (20), (24)).

use crate::linalg::{svd_truncated, Svd, SvdMethod};
use crate::tensor::Tensor;

/// The SVD factors of a compressed matrix gradient, as transmitted.
#[derive(Debug, Clone)]
pub struct SvdCompressed {
    /// m×ν left singular vectors.
    pub u: Tensor,
    /// ν singular values (the diagonal of Σ).
    pub s: Vec<f32>,
    /// n×ν right singular vectors.
    pub v: Tensor,
    /// original shape (m, n)
    pub shape: (usize, usize),
}

impl SvdCompressed {
    /// Rank ν.
    pub fn rank(&self) -> usize {
        self.s.len()
    }

    /// Total f32 elements across factors (U, diag Σ, V) — the quantity
    /// inequality (8) compares against m·n.
    pub fn factor_elems(&self) -> usize {
        self.u.len() + self.s.len() + self.v.len()
    }
}

/// ℂ for matrices: truncated SVD keeping `nu` singular values.
pub fn compress_svd(g: &Tensor, nu: usize, method: SvdMethod) -> SvdCompressed {
    assert_eq!(g.ndim(), 2, "compress_svd expects a matrix");
    let (m, n) = (g.shape()[0], g.shape()[1]);
    let svd = svd_truncated(g, nu, method);
    SvdCompressed { u: svd.u, s: svd.s, v: svd.v, shape: (m, n) }
}

/// ℂ⁻¹ for matrices: U·diag(s)·Vᵀ (paper eq. (24)).
pub fn decompress_svd(c: &SvdCompressed) -> Tensor {
    let svd = Svd { u: c.u.clone(), s: c.s.clone(), v: c.v.clone() };
    svd.reconstruct()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::rank::svd_rank;
    use crate::linalg::qr_thin;
    use crate::util::Rng;

    /// Low-rank-plus-noise matrix similar to real FC-layer gradients.
    fn lowrank_noise(m: usize, n: usize, r: usize, noise: f32, rng: &mut Rng) -> Tensor {
        let qa = qr_thin(&Tensor::randn(&[m, r], rng)).q;
        let qb = qr_thin(&Tensor::randn(&[n, r], rng)).q;
        let mut us = qa.clone();
        for i in 0..m {
            for j in 0..r {
                let v = us.get2(i, j) * (10.0 / (1 + j) as f32);
                us.set2(i, j, v);
            }
        }
        let mut a = crate::linalg::matmul_nt(&us, &qb);
        let eps = Tensor::randn(&[m, n], rng);
        a.axpy(noise, &eps);
        a
    }

    #[test]
    fn roundtrip_small_error_on_lowrank_gradient() {
        let mut rng = Rng::new(50);
        let g = lowrank_noise(40, 60, 5, 0.01, &mut rng);
        let nu = svd_rank(40, 60, 0.3); // 12 >= true rank 5
        let c = compress_svd(&g, nu, SvdMethod::Jacobi);
        let rec = decompress_svd(&c);
        assert!(g.rel_err(&rec) < 0.05, "err {}", g.rel_err(&rec));
    }

    #[test]
    fn compression_reduces_elements() {
        let mut rng = Rng::new(51);
        let g = Tensor::randn(&[200, 784], &mut rng);
        for p in [0.1, 0.2, 0.3] {
            let nu = svd_rank(200, 784, p);
            let c = compress_svd(&g, nu, SvdMethod::Auto);
            assert!(c.factor_elems() < g.len(), "p={p}");
            assert_eq!(c.rank(), nu);
        }
    }

    #[test]
    fn decompress_shape_matches_original() {
        let mut rng = Rng::new(52);
        let g = Tensor::randn(&[17, 9], &mut rng);
        let c = compress_svd(&g, 3, SvdMethod::Jacobi);
        let rec = decompress_svd(&c);
        assert_eq!(rec.shape(), g.shape());
    }

    #[test]
    fn full_rank_is_lossless() {
        let mut rng = Rng::new(53);
        let g = Tensor::randn(&[12, 8], &mut rng);
        let c = compress_svd(&g, 8, SvdMethod::Jacobi);
        let rec = decompress_svd(&c);
        assert!(g.rel_err(&rec) < 1e-4);
    }

    #[test]
    fn wide_and_tall_matrices() {
        let mut rng = Rng::new(54);
        for shape in [[8, 30], [30, 8]] {
            let g = Tensor::randn(&shape, &mut rng);
            let c = compress_svd(&g, 4, SvdMethod::Jacobi);
            assert_eq!(c.u.shape(), &[shape[0], 4]);
            assert_eq!(c.v.shape(), &[shape[1], 4]);
            let rec = decompress_svd(&c);
            assert_eq!(rec.shape(), g.shape());
        }
    }
}
