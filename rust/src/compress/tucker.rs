//! Tucker (HOSVD) compression of 4-D convolution gradients
//! (paper eq. (9), (21), (25)).
//!
//! HOSVD: factor matrix Fᵢ = the rᵢ leading left singular vectors of the
//! mode-i unfolding; core 𝔊 = 𝔛 ×₁ F₁ᵀ ×₂ F₂ᵀ … ×_N F_Nᵀ.
//! Reconstruction is 𝔊 ×₁ F₁ ×₂ F₂ … ×_N F_N.

use crate::linalg::{svd_truncated, SvdMethod};
use crate::tensor::{mode_n_product, mode_n_product_t, unfold, Tensor};

/// The Tucker factors of a compressed tensor gradient, as transmitted.
#[derive(Debug, Clone)]
pub struct TuckerCompressed {
    /// Core tensor 𝔊 ∈ R^{r₁×…×r_N}.
    pub core: Tensor,
    /// Factor matrices Fᵢ ∈ R^{Iᵢ×rᵢ}.
    pub factors: Vec<Tensor>,
    /// Original shape (I₁, …, I_N).
    pub shape: Vec<usize>,
}

impl TuckerCompressed {
    /// Per-mode ranks.
    pub fn ranks(&self) -> Vec<usize> {
        self.core.shape().to_vec()
    }

    /// Total f32 elements across core + factors — the quantity
    /// inequality (11) compares against ∏Iᵢ.
    pub fn factor_elems(&self) -> usize {
        self.core.len() + self.factors.iter().map(|f| f.len()).sum::<usize>()
    }
}

/// ℂ for tensors: HOSVD with per-mode ranks `ranks`.
pub fn compress_tucker(g: &Tensor, ranks: &[usize], method: SvdMethod) -> TuckerCompressed {
    let ndim = g.ndim();
    assert_eq!(ranks.len(), ndim, "one rank per mode");
    for (i, (&r, &d)) in ranks.iter().zip(g.shape().iter()).enumerate() {
        assert!(r >= 1 && r <= d, "rank {r} invalid for mode {i} (dim {d})");
    }

    // Factor matrices: leading left singular vectors of each unfolding.
    let mut factors = Vec::with_capacity(ndim);
    for mode in 0..ndim {
        let unf = unfold(g, mode); // I_mode × rest
        let svd = svd_truncated(&unf, ranks[mode], method);
        factors.push(svd.u); // I_mode × r_mode
    }

    // Core: project onto the factor bases, G = X ×_i Fᵢᵀ — the packed
    // GEMM reads Fᵢ through a strided view, no transpose copies.
    let mut core = g.clone();
    for (mode, f) in factors.iter().enumerate() {
        core = mode_n_product_t(&core, mode, f);
    }

    TuckerCompressed { core, factors, shape: g.shape().to_vec() }
}

/// ℂ⁻¹ for tensors: 𝔊 ×₁ F₁ … ×_N F_N (paper eq. (25)).
pub fn decompress_tucker(c: &TuckerCompressed) -> Tensor {
    let mut out = c.core.clone();
    for (mode, f) in c.factors.iter().enumerate() {
        out = mode_n_product(&out, mode, f);
    }
    debug_assert_eq!(out.shape(), &c.shape[..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::rank::tucker_ranks;
    use crate::util::Rng;

    /// Build a tensor with exact multilinear rank `ranks`.
    fn exact_rank_tensor(dims: &[usize], ranks: &[usize], rng: &mut Rng) -> Tensor {
        let core = Tensor::randn(ranks, rng);
        let mut x = core;
        for (mode, (&d, &r)) in dims.iter().zip(ranks.iter()).enumerate() {
            let f = crate::linalg::qr_thin(&Tensor::randn(&[d, r], rng)).q;
            x = mode_n_product(&x, mode, &f);
        }
        x
    }

    #[test]
    fn exact_rank_tensor_reconstructs_losslessly() {
        let mut rng = Rng::new(60);
        let dims = [8, 6, 3, 3];
        let true_ranks = [3, 2, 2, 2];
        let x = exact_rank_tensor(&dims, &true_ranks, &mut rng);
        let c = compress_tucker(&x, &true_ranks, SvdMethod::Jacobi);
        let rec = decompress_tucker(&c);
        assert!(x.rel_err(&rec) < 1e-3, "err {}", x.rel_err(&rec));
    }

    #[test]
    fn full_ranks_are_lossless() {
        let mut rng = Rng::new(61);
        let dims = [4, 5, 3, 2];
        let x = Tensor::randn(&dims, &mut rng);
        let c = compress_tucker(&x, &dims, SvdMethod::Jacobi);
        let rec = decompress_tucker(&c);
        assert!(x.rel_err(&rec) < 1e-3, "err {}", x.rel_err(&rec));
    }

    #[test]
    fn paper_conv_shapes_reduce_size() {
        let mut rng = Rng::new(62);
        // conv2 of the MNIST CNN: 32x16x3x3
        let dims = [32usize, 16, 3, 3];
        let x = Tensor::randn(&dims, &mut rng);
        for p in [0.1, 0.2, 0.3] {
            let ranks = tucker_ranks(&dims, p);
            let c = compress_tucker(&x, &ranks, SvdMethod::Auto);
            assert!(c.factor_elems() < x.len(), "p={p}");
            assert_eq!(c.ranks(), ranks);
            let rec = decompress_tucker(&c);
            assert_eq!(rec.shape(), &dims);
        }
    }

    #[test]
    fn error_decreases_with_rank() {
        let mut rng = Rng::new(63);
        let dims = [16, 8, 3, 3];
        let x = exact_rank_tensor(&dims, &[8, 4, 3, 3], &mut rng);
        let mut last = f32::MAX;
        for p in [0.15, 0.4, 0.8, 1.0] {
            let ranks = tucker_ranks(&dims, p);
            let c = compress_tucker(&x, &ranks, SvdMethod::Jacobi);
            let err = x.rel_err(&decompress_tucker(&c));
            assert!(err <= last + 1e-4, "p={p}: {err} > {last}");
            last = err;
        }
        assert!(last < 1e-3);
    }

    #[test]
    fn core_energy_equals_projection() {
        // HOSVD property: ||G||_F <= ||X||_F (orthogonal projections)
        let mut rng = Rng::new(64);
        let x = Tensor::randn(&[6, 5, 4], &mut rng);
        let c = compress_tucker(&x, &[3, 3, 2], SvdMethod::Jacobi);
        assert!(c.core.fro_norm() <= x.fro_norm() * (1.0 + 1e-5));
    }

    #[test]
    fn works_on_matrices_too() {
        // Tucker on a 2-D tensor degenerates to a two-sided SVD projection.
        let mut rng = Rng::new(65);
        let x = Tensor::randn(&[10, 8], &mut rng);
        let c = compress_tucker(&x, &[10, 8], SvdMethod::Jacobi);
        let rec = decompress_tucker(&c);
        assert!(x.rel_err(&rec) < 1e-3);
    }
}
