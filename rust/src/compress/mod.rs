//! The compression operator ℂ and its inverse ℂ⁻¹ (paper §II-A, §III-A).
//!
//! * Matrix gradients (fully connected layers) → truncated SVD with
//!   rank ν = ⌈p·min(D_out, D_in)⌉ (eq. (20), (22)).
//! * 4-D tensor gradients (convolution kernels) → Tucker/HOSVD with
//!   per-mode ranks rᵢ = ⌈p·Iᵢ⌉ (eq. (21), (23)).
//! * Bias vectors are not compressed, only quantized (eq. (26)).
//!
//! [`rank`] computes the paper's rank rules and the wire-size
//! inequalities (8)/(11) that decide whether compression pays off.
//!
//! [`pipeline`] composes these operators (and the LAQ quantizer) into
//! first-class compression pipelines with a spec grammar, a preset
//! registry, and the dual-side downlink codec (DESIGN.md §7).

pub mod pipeline;
pub mod rank;
mod svd;
mod tucker;

pub use pipeline::{
    BuildCtx, CompressionPipeline, DownlinkDecoder, DownlinkEncoder, Feedback, PipelineClient,
    PipelineServer, PipelineSpec, Quantizer, QuantizerSpec, RankReducer, ReducePlan, ReducerSpec,
};
pub use rank::{svd_rank, tucker_ranks, svd_is_smaller, tucker_is_smaller};
pub use svd::{SvdCompressed, compress_svd, decompress_svd};
pub use tucker::{TuckerCompressed, compress_tucker, decompress_tucker};
