//! Tiny CLI argument parser (clap is unavailable offline — DESIGN.md §4).
//!
//! Grammar: `qrr <command> [positional…] [--key value | --flag]…`

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// first non-flag token (subcommand)
    pub command: String,
    /// remaining non-flag tokens
    pub positional: Vec<String>,
    /// `--key value` pairs
    pub options: BTreeMap<String, String>,
    /// bare `--flag`s
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (e.g. `std::env::args().skip(1)`).
    pub fn parse(tokens: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                // --key=value or --key value or --flag
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else if out.command.is_empty() {
                out.command = tok;
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Option lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    /// Option parsed into any FromStr type.
    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str) -> anyhow::Result<Option<T>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("--{key}: cannot parse {v:?}")),
        }
    }

    /// Flag presence.
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn command_and_positional() {
        let a = parse("exp table1 extra");
        assert_eq!(a.command, "exp");
        assert_eq!(a.positional, vec!["table1", "extra"]);
    }

    #[test]
    fn options_both_syntaxes() {
        let a = parse("exp table1 --iters 50 --out=results");
        assert_eq!(a.get("iters"), Some("50"));
        assert_eq!(a.get("out"), Some("results"));
    }

    #[test]
    fn flags_vs_options() {
        let a = parse("train --quiet --config cfg.json --verbose");
        assert!(a.has_flag("quiet"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.get("config"), Some("cfg.json"));
    }

    #[test]
    fn parsed_typed() {
        let a = parse("exp --iters 50");
        assert_eq!(a.get_parsed::<u64>("iters").unwrap(), Some(50));
        assert_eq!(a.get_parsed::<u64>("missing").unwrap(), None);
        let b = parse("exp --iters abc");
        assert!(b.get_parsed::<u64>("iters").is_err());
    }

    #[test]
    fn negative_number_as_value() {
        let a = parse("exp --offset -5");
        assert_eq!(a.get("offset"), Some("-5"));
    }
}
