//! Paper Figure 1: singular-value spectrum of an FC-layer gradient —
//! regenerates the series and benches the SVD engines on that matrix
//! through the shared suite runner (the same `svd/*` cases `qrr bench
//! kernels` runs, plus the exact-Jacobi reference).

fn main() {
    let (sigmas, rank95) = qrr::experiments::fig1::spectrum(10, 256, 42);
    println!("fig1: dJ/dW1 spectrum (200 values)");
    println!(
        "  sigma_0={:.4}  sigma_9={:.4}  sigma_49={:.4}  sigma_199={:.6}",
        sigmas[0], sigmas[9], sigmas[49], sigmas[199]
    );
    println!("  rank capturing 95% energy: {rank95} / 200 (paper: 'only a few')");

    qrr::bench_util::suites::run_standalone("fig1", |suite| {
        qrr::bench_util::suites::svd_engine_cases(suite);
        // the exact-engine reference on the same gradient-shaped matrix
        use qrr::tensor::Tensor;
        use qrr::util::Rng;
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[200, 784], &mut rng);
        suite.case("svd/jacobi_exact_200x784", None, || {
            qrr::linalg::svd_jacobi(&a)
        });
    });
}
