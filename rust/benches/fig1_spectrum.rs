//! Paper Figure 1: singular-value spectrum of an FC-layer gradient —
//! regenerates the series and benches the SVD engines on that matrix.

fn main() {
    let (sigmas, rank95) = qrr::experiments::fig1::spectrum(10, 256, 42);
    println!("fig1: dJ/dW1 spectrum (200 values)");
    println!(
        "  sigma_0={:.4}  sigma_9={:.4}  sigma_49={:.4}  sigma_199={:.6}",
        sigmas[0], sigmas[9], sigmas[49], sigmas[199]
    );
    println!("  rank capturing 95% energy: {rank95} / 200 (paper: 'only a few')");

    // bench the two SVD engines on the same gradient-shaped matrix
    use qrr::linalg::{svd_truncated, SvdMethod};
    use qrr::tensor::Tensor;
    use qrr::util::Rng;
    let mut rng = Rng::new(1);
    let a = Tensor::randn(&[200, 784], &mut rng);
    let bench = qrr::bench_util::Bench::from_env();
    for k in [20, 60] {
        bench.run(&format!("fig1/svd_randomized_k{k}"), None, || {
            svd_truncated(
                &a,
                k,
                SvdMethod::Randomized { oversample: 8, power_iters: 2, seed: 1 },
            )
        });
    }
    bench.run("fig1/svd_jacobi_exact_200x784", None, || {
        qrr::linalg::svd_jacobi(&a)
    });
}
