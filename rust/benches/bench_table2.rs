//! Paper Table II / Figure 3: CNN on MNIST (Tucker-compressed conv
//! gradients). Reduced-scale regeneration through the shared suite
//! runner; `qrr exp table2 --iters 1000` for full scale.

fn main() {
    let mut base = qrr::config::ExperimentConfig::table2_default();
    base.clients = 10;
    base.batch = 32;
    base.train_n = 2_000;
    base.test_n = 400;
    base.lr_schedule = vec![(0, 0.02)];
    qrr::bench_util::suites::run_table_bench(
        "table2_cnn_mnist",
        base,
        &qrr::bench_util::suites::fixed_p_lineup(),
    );
}
