//! Paper Table II / Figure 3: CNN on MNIST (Tucker-compressed conv
//! gradients). Reduced-scale regeneration; `qrr exp table2 --iters 1000`
//! for full scale.

mod common;

fn main() {
    let mut base = qrr::config::ExperimentConfig::table2_default();
    base.clients = 10;
    base.batch = 32;
    base.train_n = 2_000;
    base.test_n = 400;
    base.lr_schedule = vec![(0, 0.02)];
    common::run_table_bench("table2_cnn_mnist", base, &common::fixed_p_lineup());
}
