//! Paper Table I / Figure 2: MLP on MNIST — SGD vs SLAQ vs QRR(p).
//! Reduced-scale regeneration through the shared suite runner;
//! `qrr exp table1 --iters 1000` for the paper's full scale.

fn main() {
    let mut base = qrr::config::ExperimentConfig::table1_default();
    base.clients = 10;
    base.batch = 128;
    base.train_n = 8_000;
    base.test_n = 1_500;
    base.lr_schedule = vec![(0, 0.01)];
    qrr::bench_util::suites::run_table_bench(
        "table1_mlp_mnist",
        base,
        &qrr::bench_util::suites::fixed_p_lineup(),
    );
}
