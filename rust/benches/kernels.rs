//! Micro-benchmarks of every hot-path primitive (the perf-pass raw
//! material, EXPERIMENTS.md §Perf): GEMM, SVD engines, Tucker, LAQ
//! quantizer + bit-packing, wire encode/decode, full QRR encode.

use qrr::bench_util::Bench;
use qrr::compress::{compress_svd, compress_tucker, tucker_ranks};
use qrr::linalg::{matmul, svd_truncated, SvdMethod};
use qrr::net::{ClientUpdate, Decoder, Encoder};
use qrr::qrr::{ClientCodec, QrrConfig};
use qrr::quant::{pack_codes, quantize};
use qrr::tensor::Tensor;
use qrr::util::Rng;

fn main() {
    let bench = Bench::from_env();
    let mut rng = Rng::new(7);

    // GEMM at the model's shapes
    for &(m, k, n, tag) in &[
        (512usize, 784usize, 200usize, "fc1_fwd"),
        (200, 512, 784, "fc1_bwd"),
        (512, 200, 10, "fc2_fwd"),
    ] {
        let a = Tensor::randn(&[m, k], &mut rng);
        let b = Tensor::randn(&[k, n], &mut rng);
        let flops = 2.0 * (m * k * n) as f64;
        bench.run(&format!("gemm/{tag}_{m}x{k}x{n}"), Some(flops), || matmul(&a, &b));
    }

    // SVD engines on the MLP's big gradient
    let g = Tensor::randn(&[200, 784], &mut rng);
    for (label, method) in [
        (
            "randomized_k20",
            SvdMethod::Randomized { oversample: 8, power_iters: 2, seed: 1 },
        ),
        (
            "randomized_k60",
            SvdMethod::Randomized { oversample: 8, power_iters: 2, seed: 1 },
        ),
    ] {
        let k = if label.ends_with("20") { 20 } else { 60 };
        bench.run(&format!("svd/{label}_200x784"), None, || {
            svd_truncated(&g, k, method)
        });
    }
    bench.run("svd/compress_p0.3_200x784", None, || {
        compress_svd(&g, 60, SvdMethod::Auto)
    });

    // Tucker on the paper's conv shapes
    let conv = Tensor::randn(&[32, 16, 3, 3], &mut rng);
    let ranks = tucker_ranks(&[32, 16, 3, 3], 0.3);
    bench.run("tucker/compress_p0.3_32x16x3x3", None, || {
        compress_tucker(&conv, &ranks, SvdMethod::Auto)
    });
    let conv_big = Tensor::randn(&[128, 64, 3, 3], &mut rng);
    let ranks_big = tucker_ranks(&[128, 64, 3, 3], 0.3);
    bench.run("tucker/compress_p0.3_128x64x3x3", None, || {
        compress_tucker(&conv_big, &ranks_big, SvdMethod::Auto)
    });

    // LAQ quantizer + bit packing
    let n = 159_010; // full MLP gradient element count
    let flat = Tensor::randn(&[n], &mut rng);
    let prev = Tensor::zeros(&[n]);
    bench.run("quant/laq_beta8_159k", Some(n as f64), || {
        quantize(&flat, &prev, 8)
    });
    let codes: Vec<u32> = (0..n).map(|i| (i % 256) as u32).collect();
    bench.run("quant/pack_beta8_159k", Some(n as f64), || {
        pack_codes(&codes, 8)
    });

    // full QRR client encode (MLP shapes, p=0.2)
    let shapes = vec![vec![200, 784], vec![200], vec![10, 200], vec![10]];
    let grads: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect();
    let mut codec = ClientCodec::new(&shapes, QrrConfig::with_p(0.2));
    bench.run("qrr/encode_mlp_p0.2", None, || codec.encode(&grads));

    // wire encode/decode of the QRR update
    let mut codec2 = ClientCodec::new(&shapes, QrrConfig::with_p(0.2));
    let update = ClientUpdate::Qrr { msgs: codec2.encode(&grads) };
    let bytes_per = (update.payload_bits() / 8) as f64;
    bench.run("wire/encode_qrr_mlp", Some(bytes_per), || {
        Encoder::new(&update, 0, 0)
    });
    let bytes = Encoder::new(&update, 0, 0);
    bench.run("wire/decode_qrr_mlp", Some(bytes_per), || {
        Decoder::decode(&bytes).unwrap()
    });

    // native model grad step (the L3-side compute baseline)
    use qrr::model::{native::NativeModel, ModelKind, ModelOps, ModelSpec};
    let model = NativeModel::new(ModelKind::Mlp);
    let spec = ModelSpec::new(ModelKind::Mlp);
    let params = spec.init_params(1);
    let x = Tensor::randn(&[128, 784], &mut rng);
    let y: Vec<u32> = (0..128).map(|i| (i % 10) as u32).collect();
    bench.run("model/mlp_grad_b128", None, || model.loss_grad(&params, &x, &y));

    // QR on the randomized-SVD intermediate shapes
    let tall = Tensor::randn(&[784, 68], &mut rng);
    bench.run("qr/thin_784x68", None, || qrr::linalg::qr_thin(&tall));
    let mid = Tensor::randn(&[200, 68], &mut rng);
    bench.run("qr/thin_200x68", None, || qrr::linalg::qr_thin(&mid));
}

// appended: QR micro-bench (perf-pass investigation)
