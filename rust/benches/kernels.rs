//! Micro-benchmarks of every hot-path primitive (the perf-pass raw
//! material): GEMM/matvec, QR, SVD engines, Tucker, LAQ quantizer +
//! bit-packing, wire encode/decode across all entry kinds, full QRR
//! encode/decode (serial + pooled).
//!
//! Thin wrapper over `bench_util::suites::kernel_cases` — the same
//! registry `qrr bench kernels` runs, so `cargo bench` and the CI perf
//! gate share one code path. Set `QRR_BENCH_JSON=<dir>` to also emit
//! `BENCH_kernels.json`.

fn main() {
    qrr::bench_util::suites::run_standalone("kernels", qrr::bench_util::suites::kernel_cases);
}
