//! Paper §III-B: client-side memory and compute overhead of QRR / SLAQ
//! relative to SGD (paper: QRR 1.2× mem, 3.82× time; SLAQ 13× mem,
//! 1.08× time). Per-scheme step timings are re-emitted through the
//! shared suite report so `QRR_BENCH_JSON=<dir>` yields
//! `BENCH_overhead.json` in the same schema as every other bench.

use std::time::Duration;

use qrr::bench_util::{suites, BenchResult, SuiteReport};

fn main() {
    let kind = if std::env::var("QRR_BENCH_FAST").is_ok() {
        qrr::model::ModelKind::Mlp
    } else {
        qrr::model::ModelKind::Vgg // the paper measures on the VGG setup
    };
    let batch = if std::env::var("QRR_BENCH_FAST").is_ok() { 16 } else { 64 };
    let rows = qrr::experiments::overhead::measure(kind, batch).expect("measure");
    println!("\nscheme        mem(bytes)    mem xSGD   step(ms)   time xSGD  (paper: QRR 1.2x/3.82x, SLAQ 13x/1.08x)");
    for r in &rows {
        println!(
            "{:<12} {:>11} {:>10.2}x {:>10.1} {:>10.2}x",
            r.scheme,
            r.mem_bytes,
            r.mem_ratio,
            r.step_secs * 1e3,
            r.time_ratio
        );
    }

    let mode = if std::env::var("QRR_BENCH_FAST").is_ok() { "fast" } else { "full" };
    let report = SuiteReport {
        suite: "overhead".into(),
        mode: mode.into(),
        threads: qrr::exec::default_threads(),
        cases: rows
            .iter()
            .map(|r| BenchResult {
                name: format!("overhead/{}_step", r.scheme),
                samples: 1,
                median: Duration::from_secs_f64(r.step_secs),
                mad: Duration::ZERO,
                units_per_iter: None,
                extras: Vec::new(),
            })
            .collect(),
    };
    suites::maybe_write_json(&report);
}
