//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. SVD engine: randomized power-iteration depth / oversampling vs
//!    accuracy and speed (why the default is q=2, o=8).
//! 2. Quantization depth β: reconstruction error vs wire bits (why the
//!    paper's β=8 sits at the knee).
//! 3. Compression fraction p: factor size vs reconstruction error
//!    (the inequality-(8) regime the paper targets).
//!
//! Timed cases run through the shared `bench_util::Suite` runner; set
//! `QRR_BENCH_JSON=<dir>` to emit `BENCH_ablations.json`.

use qrr::bench_util::{suites, Bench, Suite};
use qrr::compress::{compress_svd, decompress_svd, svd_rank};
use qrr::linalg::{matmul, qr_thin, svd_truncated, SvdMethod};
use qrr::qrr::{ClientCodec, QrrConfig, ServerCodec};
use qrr::tensor::Tensor;
use qrr::util::Rng;

/// Gradient-shaped matrix: strong low-rank head + broadband tail.
fn gradient_like(m: usize, n: usize, head: usize, rng: &mut Rng) -> Tensor {
    let qa = qr_thin(&Tensor::randn(&[m, head], rng)).q;
    let qb = qr_thin(&Tensor::randn(&[n, head], rng)).q;
    let mut us = qa.clone();
    for i in 0..m {
        for j in 0..head {
            let v = us.get2(i, j) * 20.0 / (1 + j * j) as f32;
            us.set2(i, j, v);
        }
    }
    let mut a = qrr::linalg::matmul_nt(&us, &qb);
    let noise = Tensor::randn(&[m, n], rng);
    a.axpy(0.05, &noise);
    a
}

fn main() {
    let mut suite = Suite::new("ablations", Bench::from_env());
    let mut rng = Rng::new(99);
    let g = gradient_like(200, 784, 12, &mut rng);
    let k = 40;

    println!("-- ablation 1: randomized SVD (power iters q, oversample o) --");
    let exact = svd_truncated(&g, k, SvdMethod::Jacobi);
    let exact_err = g.sub(&exact.reconstruct()).fro_norm();
    println!("exact Jacobi truncation error: {exact_err:.4} (reference)");
    for (q, o) in [(0usize, 8usize), (1, 8), (2, 8), (2, 4), (2, 16), (3, 8)] {
        let m = SvdMethod::Randomized { oversample: o, power_iters: q, seed: 5 };
        let svd = svd_truncated(&g, k, m);
        let err = g.sub(&svd.reconstruct()).fro_norm();
        let r = suite.case(&format!("svd_rand/q{q}_o{o}"), None, || {
            svd_truncated(&g, k, m)
        });
        println!(
            "    q={q} o={o}: err {err:.4} ({:+.2}% vs exact), {:.1} ms",
            100.0 * (err - exact_err) / exact_err,
            r.median.as_secs_f64() * 1e3
        );
    }

    println!("\n-- ablation 2: quantization depth beta (QRR p=0.2, MLP fc1 shape) --");
    let shapes = vec![vec![200usize, 784]];
    for beta in [2u8, 4, 6, 8, 12] {
        let cfg = QrrConfig { p: 0.2, beta, method: SvdMethod::Auto };
        let mut c = ClientCodec::new(&shapes, cfg);
        let mut s = ServerCodec::new(&shapes, cfg);
        let msgs = c.encode(std::slice::from_ref(&g));
        let bits: u64 = msgs.iter().map(|m| m.wire_bits()).sum();
        let rec = s.decode(&msgs);
        println!(
            "    beta={beta:>2}: {:>9} bits ({:5.2}% of raw), rel err {:.4}",
            bits,
            100.0 * bits as f64 / (32 * g.len()) as f64,
            g.rel_err(&rec[0])
        );
    }

    println!("\n-- ablation 3: compression fraction p (SVD path, eq. (8) regime) --");
    for p in [0.05, 0.1, 0.2, 0.3, 0.5] {
        let nu = svd_rank(200, 784, p);
        let c = compress_svd(&g, nu, SvdMethod::Auto);
        let rec = decompress_svd(&c);
        println!(
            "    p={p:<4} nu={nu:>3}: factors {:>6} elems ({:5.1}% of raw), rel err {:.4}",
            c.factor_elems(),
            100.0 * c.factor_elems() as f64 / g.len() as f64,
            g.rel_err(&rec)
        );
    }

    println!("\n-- ablation 4: GEMM block size (L3 matmul kernel) --");
    let a = Tensor::randn(&[512, 784], &mut rng);
    let b = Tensor::randn(&[784, 200], &mut rng);
    let flops = 2.0 * (512 * 784 * 200) as f64;
    suite.case("gemm/default_block64", Some(flops), || matmul(&a, &b));

    suites::maybe_write_json(&suite.finish());
}
