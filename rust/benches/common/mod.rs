//! Shared bench scaffolding: reduced-scale table regeneration used by the
//! per-table bench binaries. Scale with `QRR_BENCH_ITERS` (default 40).

use qrr::config::{ExperimentConfig, SchemeConfig};
use qrr::fl::metrics::{markdown_table, TableRow};
use qrr::fl::session::FlSessionBuilder;
use qrr::util::Timer;

/// Reduced-scale run of one table's scheme lineup; prints timings + the
/// paper-shaped markdown table and the QRR/SGD bit ratios.
pub fn run_table_bench(name: &str, base: ExperimentConfig, schemes: &[SchemeConfig]) {
    let iters: u64 = std::env::var("QRR_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(40);
    let mut rows: Vec<TableRow> = Vec::new();
    println!("== {name} (reduced: {iters} iterations; QRR_BENCH_ITERS to change) ==");
    for &scheme in schemes {
        let mut cfg = base.clone();
        cfg.scheme = scheme;
        cfg.iters = iters;
        cfg.eval_every = (iters / 4).max(1);
        let t = Timer::start();
        let report = FlSessionBuilder::new(&cfg)
            .build()
            .expect("session")
            .run()
            .expect("run");
        println!(
            "{:<44} {:>10.2} ms/iter  ({} total)",
            format!("{name}/{}", scheme.label()),
            t.millis() / iters as f64,
            format!("{:.1}s", t.secs()),
        );
        rows.push(report.history.table_row());
    }
    println!("\n{}", markdown_table(&rows));
    if let Some(sgd) = rows.iter().find(|r| r.algorithm == "SGD") {
        for r in rows.iter().filter(|r| r.algorithm.starts_with("QRR")) {
            println!(
                "{}: {:.2}% of SGD bits, accuracy {:+.2}%",
                r.algorithm,
                100.0 * r.bits as f64 / sgd.bits as f64,
                100.0 * (r.accuracy - sgd.accuracy)
            );
        }
    }
    println!();
}

/// The paper's lineup for tables I & II.
#[allow(dead_code)] // table3 links this module but uses its own lineup
pub fn fixed_p_lineup() -> Vec<SchemeConfig> {
    use qrr::config::PPolicy::*;
    vec![
        SchemeConfig::Sgd,
        SchemeConfig::Slaq,
        SchemeConfig::Qrr(Fixed(0.3)),
        SchemeConfig::Qrr(Fixed(0.2)),
        SchemeConfig::Qrr(Fixed(0.1)),
    ]
}
