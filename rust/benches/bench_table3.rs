//! Paper Table III / Figure 4: VGG-like CNN on CIFAR-10 with per-client
//! adaptive p ∈ [0.1, 0.3] and the lr 0.01 → 0.001 schedule.
//! Reduced-scale regeneration through the shared suite runner;
//! `qrr exp table3 --iters 2000` for full scale.

use qrr::config::{PPolicy, SchemeConfig};

fn main() {
    let mut base = qrr::config::ExperimentConfig::table3_default();
    base.clients = 10;
    base.batch = 16;
    base.train_n = 1_200;
    base.test_n = 200;
    // keep the two-phase schedule, scaled to the reduced run
    let iters: u64 = std::env::var("QRR_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(40);
    base.lr_schedule = vec![(0, 0.01), (iters / 2, 0.001)];
    qrr::bench_util::suites::run_table_bench(
        "table3_vgg_cifar10",
        base,
        &[
            SchemeConfig::Sgd,
            SchemeConfig::Slaq,
            SchemeConfig::Qrr(PPolicy::Adaptive { lo: 0.1, hi: 0.3 }),
        ],
    );
}
