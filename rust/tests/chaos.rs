//! Chaos suite (DESIGN.md §11): end-to-end fault injection over the
//! in-proc and TCP transports. The acceptance bar is determinism —
//! the same seed must reproduce the same per-round fault counters —
//! plus graceful degradation: no fault mix may hang or abort a round.
//!
//! The CI chaos-smoke matrix drives `env_driven_chaos_smoke` with
//! `QRR_CHAOS_SEED` / `QRR_CHAOS_MIX` (3 seeds × 3 mixes), plus two
//! `QRR_CHAOS_CONTROLLER` legs (linkaware, aimd) that hold the
//! adaptive control plane to the same determinism bar, and
//! `QRR_CHAOS_STREAMING` legs that run the streamed (chunked-framing)
//! path under the same mixes (DESIGN.md §13).

use std::time::Duration;

use qrr::compress::pipeline::PipelineSpec;
use qrr::config::{ExperimentConfig, ParticipationConfig, QuorumConfig, SchemeConfig};
use qrr::fl::metrics::History;
use qrr::fl::session::FlSessionBuilder;
use qrr::net::faults::FaultPlan;
use qrr::net::transport::TcpTransport;

/// Tiny MLP/MNIST config with a stateless (SGD) uplink — chaos drops
/// uplink frames, and only the stateless codec tolerates a lost frame
/// without desyncing its server mirror — plus a delta-coded downlink
/// so lost broadcasts exercise the snapshot-resync path.
fn chaos_cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::table1_default();
    c.scheme = SchemeConfig::Sgd;
    c.clients = 3;
    c.iters = 10;
    c.batch = 12;
    c.train_n = 240;
    c.test_n = 60;
    c.eval_every = 10;
    c.lr_schedule = vec![(0, 0.05)];
    c.participation = ParticipationConfig::Full;
    c.downlink = Some(PipelineSpec::parse("svd(p=0.1)+laq(beta=8)").unwrap());
    c
}

/// The per-round fault counters a seed must reproduce exactly.
fn counters(h: &History) -> Vec<(u32, u32, u32, u32, u32, u32, u64)> {
    h.rounds
        .iter()
        .map(|r| {
            (
                r.clients_dropped,
                r.clients_timed_out,
                r.clients_corrupt,
                r.clients_late,
                r.resyncs,
                r.comms,
                r.bits,
            )
        })
        .collect()
}

/// Same, minus `clients_late` — under real sockets, whether a frame
/// beats the first deadline is a wall-clock race, not plan-determined.
fn fault_counters(h: &History) -> Vec<(u32, u32, u32, u32, u32, u64)> {
    h.rounds
        .iter()
        .map(|r| {
            (
                r.clients_dropped,
                r.clients_timed_out,
                r.clients_corrupt,
                r.resyncs,
                r.comms,
                r.bits,
            )
        })
        .collect()
}

/// Every upload is accounted exactly once per round:
/// delivered + corrupt + timed out + dropped = cohort.
fn assert_accounting(h: &History, cohort: u32) {
    for r in &h.rounds {
        assert_eq!(
            r.comms + r.clients_corrupt + r.clients_timed_out + r.clients_dropped,
            cohort,
            "round {} loses track of an upload: {r:?}",
            r.iter
        );
    }
}

fn run_inproc(cfg: &ExperimentConfig, plan: &FaultPlan, quorum: &str) -> History {
    FlSessionBuilder::new(cfg)
        .chaos(plan.clone())
        .quorum(QuorumConfig::parse(quorum).unwrap())
        .recv_timeout(Duration::from_millis(20))
        .quiet()
        .build()
        .unwrap()
        .run()
        .unwrap()
        .history
}

fn run_tcp(cfg: &ExperimentConfig, plan: &FaultPlan, quorum: &str) -> History {
    let transport = TcpTransport::bind("127.0.0.1:0").unwrap();
    FlSessionBuilder::new(cfg)
        .transport(Box::new(transport))
        .chaos(plan.clone())
        .quorum(QuorumConfig::parse(quorum).unwrap())
        .recv_timeout(Duration::from_millis(250))
        .quiet()
        .build()
        .unwrap()
        .run()
        .unwrap()
        .history
}

#[test]
fn inproc_chaos_is_deterministic_and_degrades_gracefully() {
    // every fault kind at once, well over the 2% combined-rate bar:
    // uplink drop/corrupt/dup/delay/disconnect plus downlink drops
    // aggressive enough to force snapshot resyncs
    let spec = "drop=0.15,corrupt=0.1,dup=0.1,delay=0.1,disconnect=0.1,down.drop=0.4";
    let cfg = chaos_cfg();

    // fault decisions are seed-dependent, so scan a few seeds for one
    // whose schedule exercises both loss paths within 10 rounds (for
    // any fixed seed the outcome is the same on every run)
    let mut chosen = None;
    for seed in [7u64, 11, 23] {
        let mut plan = FaultPlan::parse(spec).unwrap();
        plan.seed = seed;
        let h = run_inproc(&cfg, &plan, "0.5:2:5");
        assert_eq!(h.iterations(), 10, "seed {seed}: chaos run did not complete");
        assert_accounting(&h, 3);
        if h.total_resyncs() > 0 && h.total_timed_out() > 0 {
            chosen = Some((plan, h));
            break;
        }
    }
    let (plan, first) = chosen.expect("no scanned seed exercised resync + loss paths");

    // the headline determinism bar: the same seed reproduces every
    // per-round counter — including which frames arrived late — twice
    let second = run_inproc(&cfg, &plan, "0.5:2:5");
    assert_eq!(counters(&first), counters(&second), "same seed, different schedule");

    // degradation, not collapse: most uploads still land and the
    // model still produces a finite evaluation
    assert!(first.total_comms() > 0, "no upload survived the chaos plan");
    assert!(first.evals.last().unwrap().loss.is_finite());
    assert!(first.total_resyncs() >= 1, "downlink drops never forced a resync");
}

#[test]
fn tcp_chaos_counters_reproduce_across_runs() {
    // real sockets under the CI-style mix: drops, corruption and
    // duplicates (no delay — socket scheduling owns the clock there)
    let spec = "drop=0.1,corrupt=0.05,dup=0.1,down.drop=0.3,seed=7";
    let plan = FaultPlan::parse(spec).unwrap();
    let mut cfg = chaos_cfg();
    cfg.iters = 6;
    cfg.eval_every = 6;

    let a = run_tcp(&cfg, &plan, "0.5:2:10");
    let b = run_tcp(&cfg, &plan, "0.5:2:10");
    assert_eq!(a.iterations(), 6);
    assert_accounting(&a, 3);
    assert_eq!(
        fault_counters(&a),
        fault_counters(&b),
        "same seed over TCP, different fault schedule"
    );
}

#[test]
fn quorum_lets_rounds_proceed_without_stragglers() {
    // drop-heavy uplink with a 1/3 quorum and no re-polls: each round
    // proceeds the moment the quorum is met (or the deadline passes) —
    // the run must finish with losses recorded, not stall on them
    let plan = FaultPlan::parse("drop=0.3,seed=7").unwrap();
    let cfg = chaos_cfg();
    let h = run_inproc(&cfg, &plan, "0.34:0");
    assert_eq!(h.iterations(), 10);
    assert_accounting(&h, 3);
    assert!(
        h.total_timed_out() > 0,
        "a 30% drop rate over 30 uploads lost nothing — chaos not applied?"
    );
    // strict quorum on the same seed sees the identical loss schedule:
    // quorum changes how long the server waits, never what arrives
    let strict = run_inproc(&cfg, &plan, "1.0:2:5");
    assert_eq!(h.total_timed_out(), strict.total_timed_out());
    assert_eq!(h.total_comms(), strict.total_comms());
}

#[test]
fn streamed_chaos_keeps_accounting_exact() {
    // chunk-granular faults (DESIGN.md §13): a client whose upload
    // loses one layer chunk times out at the deadline, one whose chunk
    // is corrupted in flight is counted corrupt via the digest's
    // per-client failure flags — and never both, so the
    // delivered + corrupt + timed-out + dropped partition stays exact.
    // Held to the same determinism bar as the whole-frame tests.
    let spec = "drop=0.1,corrupt=0.1,dup=0.1,down.drop=0.2";
    let mut cfg = chaos_cfg();
    cfg.streaming = true;

    let total_corrupt =
        |h: &History| h.rounds.iter().map(|r| r.clients_corrupt as u64).sum::<u64>();
    let mut chosen = None;
    for seed in [7u64, 11, 23, 41] {
        let mut plan = FaultPlan::parse(spec).unwrap();
        plan.seed = seed;
        let h = run_inproc(&cfg, &plan, "0.5:2:5");
        assert_eq!(h.iterations(), 10, "seed {seed}: streamed chaos run did not complete");
        assert_accounting(&h, 3);
        if h.total_timed_out() > 0 && total_corrupt(&h) > 0 {
            chosen = Some((plan, h));
            break;
        }
    }
    let (plan, first) = chosen.expect("no scanned seed exercised both streamed loss paths");

    let second = run_inproc(&cfg, &plan, "0.5:2:5");
    assert_eq!(
        counters(&first),
        counters(&second),
        "same seed, different streamed fault schedule"
    );
    assert!(first.total_comms() > 0, "no streamed upload survived the chaos plan");
    assert!(first.evals.last().unwrap().loss.is_finite());
}

#[test]
fn env_driven_chaos_smoke() {
    // CI matrix entry point: QRR_CHAOS_SEED × QRR_CHAOS_MIX
    // (drop2 | corrupt1 | dupreorder), run over TCP loopback twice
    // and held to the same determinism bar as the fixed tests
    let seed: u64 = std::env::var("QRR_CHAOS_SEED")
        .ok()
        .map(|v| v.parse().expect("QRR_CHAOS_SEED must be an integer"))
        .unwrap_or(1);
    let mix = std::env::var("QRR_CHAOS_MIX").unwrap_or_else(|_| "drop2".into());
    let spec = match mix.as_str() {
        "drop2" => "drop=0.02,down.drop=0.1",
        "corrupt1" => "corrupt=0.01,down.corrupt=0.1",
        "dupreorder" => "dup=0.05,delay=0.05",
        other => panic!("unknown QRR_CHAOS_MIX {other:?} (drop2|corrupt1|dupreorder)"),
    };
    let mut plan = FaultPlan::parse(spec).unwrap();
    plan.seed = seed;
    let mut cfg = chaos_cfg();
    cfg.iters = 5;
    cfg.eval_every = 5;
    // streamed legs: same mixes, but every upload crosses as per-layer
    // chunk frames with chunk-granular fault decisions (DESIGN.md §13)
    if std::env::var("QRR_CHAOS_STREAMING").map(|v| !v.is_empty()).unwrap_or(false) {
        cfg.streaming = true;
    }

    let controller = std::env::var("QRR_CHAOS_CONTROLLER")
        .ok()
        .filter(|v| !v.is_empty());
    if let Some(ctrl) = controller {
        cfg.controller = Some(
            qrr::control::ControllerConfig::parse(&ctrl)
                .expect("QRR_CHAOS_CONTROLLER must be a valid controller spec"),
        );
        // an adaptive controller folds last round's Late/Delivered
        // outcome into its next decision, and over real sockets whether
        // a frame beats the first deadline is a wall-clock race — so
        // the controller legs run in-proc, where the full counter set
        // (late included) and every per-client (p, beta, bits) decision
        // must reproduce exactly under the same chaos seed
        let a = run_inproc(&cfg, &plan, "0.5:2:10");
        let b = run_inproc(&cfg, &plan, "0.5:2:10");
        assert_eq!(a.iterations(), 5, "controller {ctrl} seed {seed}: run did not complete");
        assert_accounting(&a, 3);
        assert_eq!(
            counters(&a),
            counters(&b),
            "controller {ctrl} seed {seed}: counters not reproducible"
        );
        let decisions = |h: &History| {
            h.client_rounds
                .iter()
                .map(|c| (c.iter, c.client, c.p, c.beta, c.bits))
                .collect::<Vec<_>>()
        };
        assert!(!a.client_rounds.is_empty(), "controller run recorded no per-client telemetry");
        assert_eq!(
            decisions(&a),
            decisions(&b),
            "controller {ctrl} seed {seed}: per-client decisions not reproducible"
        );
        assert!(a.evals.last().unwrap().loss.is_finite());
        return;
    }

    let a = run_tcp(&cfg, &plan, "0.5:2:10");
    let b = run_tcp(&cfg, &plan, "0.5:2:10");
    assert_eq!(a.iterations(), 5, "mix {mix} seed {seed}: run did not complete");
    assert_accounting(&a, 3);
    assert_eq!(
        fault_counters(&a),
        fault_counters(&b),
        "mix {mix} seed {seed}: counters not reproducible"
    );
    assert!(a.evals.last().unwrap().loss.is_finite());
}
