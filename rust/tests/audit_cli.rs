//! End-to-end tests for the `qrr_audit` binary: the real source tree
//! must pass `--check`, a violating tree must fail it with file:line
//! diagnostics, and `--list-rules` must document the registry.

use std::fs;
use std::path::PathBuf;
use std::process::Command;

fn audit_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_qrr_audit"))
}

/// A scratch directory that cleans up after itself.
struct TempTree {
    root: PathBuf,
}

impl TempTree {
    fn new(tag: &str) -> Self {
        let root = std::env::temp_dir().join(format!("qrr_audit_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&root);
        fs::create_dir_all(&root).expect("create temp tree");
        TempTree { root }
    }

    fn write(&self, rel: &str, contents: &str) -> PathBuf {
        let path = self.root.join(rel);
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir).expect("create fixture dir");
        }
        fs::write(&path, contents).expect("write fixture");
        path
    }
}

impl Drop for TempTree {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.root);
    }
}

#[test]
fn real_tree_passes_check() {
    let out = audit_bin().arg("--check").output().expect("run qrr_audit");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "qrr_audit --check failed on the shipped tree:\n{stdout}\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(
        stdout.contains("0 finding(s)"),
        "expected a clean summary line, got:\n{stdout}"
    );
}

#[test]
fn violating_tree_fails_check_with_location() {
    let tree = TempTree::new("violation");
    tree.write(
        "offender.rs",
        "pub fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n",
    );
    let out = audit_bin()
        .args(["--check", "--root"])
        .arg(&tree.root)
        .output()
        .expect("run qrr_audit");
    assert!(
        !out.status.success(),
        "--check must fail on an unannotated unsafe block"
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("offender.rs:2") && stdout.contains("[unsafe-audit]"),
        "expected a file:line [unsafe-audit] diagnostic, got:\n{stdout}"
    );
}

#[test]
fn without_check_findings_are_reported_but_not_fatal() {
    let tree = TempTree::new("report_only");
    tree.write(
        "net/wire.rs",
        "// decode half\n// qrr-audit: no-panic\nfn f(v: Option<u8>) -> u8 {\n    v.unwrap()\n}\n// qrr-audit: end\n",
    );
    let out = audit_bin().arg("--root").arg(&tree.root).output().expect("run qrr_audit");
    assert!(out.status.success(), "report-only mode must exit 0");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("wire.rs:4") && stdout.contains("[no-panic]"),
        "expected the unwrap to be reported, got:\n{stdout}"
    );
}

#[test]
fn list_rules_documents_the_registry() {
    let out = audit_bin().arg("--list-rules").output().expect("run qrr_audit");
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    for rule in ["unsafe-audit", "no-alloc", "no-panic", "env-once"] {
        assert!(stdout.contains(rule), "missing rule {rule} in:\n{stdout}");
    }
}
