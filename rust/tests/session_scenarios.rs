//! Integration: scenarios that only exist because of the composable
//! session API — partial participation with link-driven client dropout
//! (selected from JSON and from the CLI), straggler deadlines, weighted
//! aggregation and the TCP transport binding, all through
//! `FlSessionBuilder`.

use std::time::Duration;

use qrr::prelude::*;

fn tiny_base() -> ExperimentConfig {
    let mut c = ExperimentConfig::table1_default();
    c.scheme = SchemeConfig::Qrr(PPolicy::Fixed(0.2));
    c.clients = 4;
    c.iters = 8;
    c.batch = 12;
    c.train_n = 240;
    c.test_n = 60;
    c.eval_every = 4;
    c.lr_schedule = vec![(0, 0.05)];
    c
}

#[test]
fn dropout_scenario_from_json_runs_end_to_end() {
    // the new scenario is fully described by config JSON — no bespoke
    // round loop anywhere
    let json = r#"{
        "name": "dropout_scenario",
        "scheme": {"kind": "qrr", "p": 0.2},
        "clients": 4,
        "iters": 8,
        "batch": 12,
        "train_n": 240,
        "test_n": 60,
        "eval_every": 4,
        "lr_schedule": [[0, 0.05]],
        "participation": {"kind": "dropout", "fraction": 0.5, "drop_prob": 0.5},
        "aggregation": "sum"
    }"#;
    let cfg = ExperimentConfig::from_json(&qrr::config::Json::parse(json).unwrap()).unwrap();
    assert_eq!(
        cfg.participation,
        ParticipationConfig::Dropout { fraction: 0.5, drop_prob: 0.5 }
    );

    let mut session = FlSessionBuilder::new(&cfg)
        .recv_timeout(Duration::from_millis(10))
        .quiet()
        .build()
        .unwrap();
    let report = session.run().unwrap();
    let h = &report.history;
    assert_eq!(h.iterations(), 8);
    // ceil(0.5*4)=2 clients sampled per round; dropout can only lose
    // uploads on top of that
    assert!(h.total_comms() <= 2 * 8, "comms {}", h.total_comms());
    assert!(h.evals.last().unwrap().loss.is_finite());
}

#[test]
fn dropout_scenario_from_cli_overrides() {
    // the same scenario selected through the CLI surface
    let args = qrr::cli::Args::parse(
        "train --participation dropout:0.5:1.0 --aggregation weighted_mean"
            .split_whitespace()
            .map(String::from),
    );
    let mut cfg = tiny_base();
    // equal links ⇒ slowness 1 ⇒ drop_prob 1 loses every upload
    cfg.link_slow_bps = 1e6;
    cfg.link_fast_bps = 1e6;
    cfg.iters = 3;
    cfg.eval_every = 3;
    qrr::experiments::apply_overrides(&mut cfg, &args).unwrap();
    assert_eq!(
        cfg.participation,
        ParticipationConfig::Dropout { fraction: 0.5, drop_prob: 1.0 }
    );
    assert_eq!(cfg.aggregation, AggregationConfig::WeightedMean);

    let mut session = FlSessionBuilder::new(&cfg)
        .recv_timeout(Duration::from_millis(10))
        .quiet()
        .build()
        .unwrap();
    let report = session.run().unwrap();
    // every upload lost, yet the rounds complete without hanging
    assert_eq!(report.history.total_comms(), 0);
    assert_eq!(report.history.iterations(), 3);
    assert!(report.history.evals.last().unwrap().loss.is_finite());
}

#[test]
fn straggler_deadline_scenario() {
    let mut cfg = tiny_base();
    cfg.scheme = SchemeConfig::Sgd;
    // SGD upload ≈ 5.09 Mbit; the slowest of the spread links (250 kbit/s)
    // needs >20 s, everyone else is comfortably under 5 s
    cfg.participation = ParticipationConfig::Deadline { secs: 5.0 };
    cfg.iters = 4;
    cfg.eval_every = 4;
    let mut session = FlSessionBuilder::new(&cfg)
        .recv_timeout(Duration::from_millis(10))
        .quiet()
        .build()
        .unwrap();
    let h = session.run().unwrap().history;
    assert_eq!(h.total_comms(), 3 * 4, "slowest client should miss every deadline");
}

#[test]
fn uniform_sampling_with_weighted_mean_learns() {
    let mut cfg = tiny_base();
    cfg.scheme = SchemeConfig::Sgd;
    cfg.participation = ParticipationConfig::Uniform { fraction: 0.75 };
    cfg.aggregation = AggregationConfig::WeightedMean;
    cfg.iters = 12;
    cfg.eval_every = 4;
    // mean scales the step ~1/participants vs sum; compensate the LR
    cfg.lr_schedule = vec![(0, 0.15)];
    let h = FlSessionBuilder::new(&cfg)
        .quiet()
        .build()
        .unwrap()
        .run()
        .unwrap()
        .history;
    // ceil(0.75*4)=3 participants per round, all delivered
    assert_eq!(h.total_comms(), 3 * 12);
    let first = h.evals.first().unwrap().loss;
    let last = h.evals.last().unwrap().loss;
    assert!(last < first, "no learning: {first} -> {last}");
}

#[test]
fn dual_side_compression_over_tcp_transport() {
    // dual-side: QRR uplink over real sockets + svd+laq downlink deltas —
    // no direction ships full-precision parameters
    let mut cfg = tiny_base();
    cfg.iters = 4;
    cfg.eval_every = 4;
    cfg.downlink = Some(PipelineSpec::parse("svd(p=0.1)+laq(beta=8)").unwrap());
    let transport = TcpTransport::bind("127.0.0.1:0").unwrap();
    let mut session = FlSessionBuilder::new(&cfg)
        .transport(Box::new(transport))
        .recv_timeout(Duration::from_secs(5))
        .quiet()
        .build()
        .unwrap();
    let report = session.run().unwrap();
    let h = &report.history;
    assert_eq!(h.total_comms(), 4 * 4, "every upload must cross the socket");
    assert!(h.total_bits() > 0);
    // downlink strictly below the full-precision broadcast baseline
    let model_params = 159_010u64;
    assert!(h.total_down_bits() < 4 * 32 * model_params);
    assert!(h.total_down_bits() > 0);
    assert!(h.evals.last().unwrap().loss.is_finite());
}

#[test]
fn tcp_binding_composes_with_dropout() {
    // real sockets + lossy participation in one builder chain: dropped
    // uploads never reach the socket and the server times out cleanly
    let mut cfg = tiny_base();
    cfg.iters = 2;
    cfg.eval_every = 2;
    cfg.link_slow_bps = 1e6;
    cfg.link_fast_bps = 1e6;
    cfg.participation = ParticipationConfig::Dropout { fraction: 1.0, drop_prob: 1.0 };
    let transport = TcpTransport::bind("127.0.0.1:0").unwrap();
    let mut session = FlSessionBuilder::new(&cfg)
        .transport(Box::new(transport))
        .recv_timeout(Duration::from_millis(50))
        .quiet()
        .build()
        .unwrap();
    let report = session.run().unwrap();
    assert_eq!(report.history.total_comms(), 0);
    assert_eq!(report.history.iterations(), 2);
}
