//! Integration tests for the adaptive compression control plane
//! (DESIGN.md §12): determinism under chaos, lockstep client/server
//! pipeline swaps across quorum re-polls, and the straggler bit
//! allocation the AIMD policy exists to produce.

use std::time::Duration;

use qrr::compress::pipeline::PipelineSpec;
use qrr::config::{ExperimentConfig, ParticipationConfig, QuorumConfig, SchemeConfig};
use qrr::control::{ClientObservation, CompressionController, ControllerConfig};
use qrr::fl::metrics::History;
use qrr::fl::session::FlSessionBuilder;
use qrr::net::faults::FaultPlan;

/// Small MLP/MNIST cohort on the default spread links (250 kbit/s up to
/// 10 Mbit/s, so client 0 is the straggler and the last client is
/// broadband).
fn spread_cfg(clients: usize, iters: u64) -> ExperimentConfig {
    let mut c = ExperimentConfig::table1_default();
    c.scheme = SchemeConfig::Sgd;
    c.clients = clients;
    c.iters = iters;
    c.batch = 8;
    c.train_n = 40 * clients;
    c.test_n = 40;
    c.eval_every = iters;
    c.lr_schedule = vec![(0, 0.05)];
    c.participation = ParticipationConfig::Full;
    c
}

fn run(cfg: &ExperimentConfig, plan: Option<&FaultPlan>, quorum: &str) -> History {
    let mut b = FlSessionBuilder::new(cfg)
        .quorum(QuorumConfig::parse(quorum).unwrap())
        .recv_timeout(Duration::from_millis(20))
        .quiet();
    if let Some(p) = plan {
        b = b.chaos(p.clone());
    }
    b.build().unwrap().run().unwrap().history
}

/// Per-round per-client decisions + spend, the controller's full output.
fn decisions(h: &History) -> Vec<(u64, u32, f64, u8, u64, char)> {
    h.client_rounds
        .iter()
        .map(|c| (c.iter, c.client, c.p, c.beta, c.bits, c.outcome.code()))
        .collect()
}

#[test]
fn controller_decisions_are_deterministic_under_chaos() {
    // the bar from DESIGN.md §12: same (chaos seed, controller) twice
    // must reproduce every per-round per-client (p, beta) decision and
    // every bits counter exactly — no wall clock or RNG in the loop
    let plan = FaultPlan::parse("drop=0.1,delay=0.15,seed=9").unwrap();
    for ctrl in [ControllerConfig::linkaware(), ControllerConfig::aimd()] {
        let mut cfg = spread_cfg(3, 6);
        cfg.controller = Some(ctrl);
        let a = run(&cfg, Some(&plan), "0.5:2:5");
        let b = run(&cfg, Some(&plan), "0.5:2:5");
        assert_eq!(a.iterations(), 6, "{}: run did not complete", ctrl.format());
        assert!(!a.client_rounds.is_empty(), "{}: no telemetry recorded", ctrl.format());
        assert_eq!(decisions(&a), decisions(&b), "{}: decisions diverged", ctrl.format());
        let bits = |h: &History| {
            h.rounds.iter().map(|r| (r.bits, r.down_bits)).collect::<Vec<_>>()
        };
        assert_eq!(bits(&a), bits(&b), "{}: bit accounting diverged", ctrl.format());
    }
}

/// A controller that flips client 1 between two incompatible wire
/// formats every round — the worst case for client/server spec
/// agreement across quorum re-polls.
struct Flipper;

impl CompressionController for Flipper {
    fn plan(&mut self, round: u64, obs: &[ClientObservation]) -> Vec<PipelineSpec> {
        obs.iter()
            .map(|o| {
                if o.client == 1 && round % 2 == 1 {
                    PipelineSpec::qrr(0.4, 6)
                } else {
                    PipelineSpec::qrr(0.2, 8)
                }
            })
            .collect()
    }

    fn label(&self) -> String {
        "flipper".into()
    }
}

#[test]
fn spec_change_swaps_client_and_server_in_lockstep_across_repolls() {
    // delay chaos pushes frames into quorum re-poll windows, so the
    // server is still collecting a round while the controller has
    // already planned the next spec flip for client 1. The swap must be
    // lockstep: a stale mirror would fail decode and show up as corrupt.
    let plan = FaultPlan::parse("delay=0.3,seed=5").unwrap();
    let cfg = spread_cfg(3, 8);
    let mut session = FlSessionBuilder::new(&cfg)
        .custom_controller(Box::new(Flipper))
        .chaos(plan)
        .quorum(QuorumConfig::parse("0.5:2:5").unwrap())
        .recv_timeout(Duration::from_millis(20))
        .quiet()
        .build()
        .unwrap();
    let history = session.run().unwrap().history;

    assert_eq!(history.iterations(), 8);
    for r in &history.rounds {
        assert_eq!(
            r.clients_corrupt, 0,
            "round {}: a flipped spec left a stale server mirror: {r:?}",
            r.iter
        );
        // every upload accounted exactly once, delay or not
        assert_eq!(
            r.comms + r.clients_corrupt + r.clients_timed_out + r.clients_dropped,
            3,
            "round {} loses track of an upload: {r:?}",
            r.iter
        );
    }
    assert!(history.total_comms() > 0, "no upload survived the flip schedule");
    // the last replan (round 7, odd) put client 1 on the alternate spec
    assert_eq!(session.client_specs()[1], PipelineSpec::qrr(0.4, 6));
    assert_eq!(session.client_specs()[0], PipelineSpec::qrr(0.2, 8));
    // the flip is visible in the telemetry: client 1 ran both formats
    let c1_betas: Vec<u8> = history
        .client_rounds
        .iter()
        .filter(|c| c.client == 1)
        .map(|c| c.beta)
        .collect();
    assert!(c1_betas.contains(&6) && c1_betas.contains(&8), "flip never took effect: {c1_betas:?}");
}

#[test]
fn aimd_underspends_stragglers_without_extra_timeouts() {
    // the acceptance scenario: a spread cohort under light drop chaos.
    // aimd must assign the straggler strictly fewer uplink bits than
    // the broadband client, and — because fault decisions are payload-
    // independent pure functions of (seed, client, round) — lose no
    // more uploads to timeouts than the link-oblivious fixed policy on
    // the same seed
    let plan = FaultPlan::parse("drop=0.02,seed=11").unwrap();
    let run_with = |ctrl: ControllerConfig| {
        let mut cfg = spread_cfg(4, 8);
        cfg.controller = Some(ctrl);
        run(&cfg, Some(&plan), "1.0:2:5")
    };
    let fixed = run_with(ControllerConfig::fixed());
    let aimd = run_with(ControllerConfig::aimd());

    let aimd_bits = aimd.bits_per_client();
    assert_eq!(aimd_bits.len(), 4);
    let straggler = aimd_bits[0];
    let broadband = aimd_bits[3];
    assert!(
        straggler < broadband,
        "aimd spent as much on the straggler as on broadband: {straggler} vs {broadband}"
    );
    // fixed is link-oblivious: every client gets the same per-round spec
    let fixed_bits = fixed.bits_per_client();
    assert_eq!(fixed_bits[0], fixed_bits[3], "fixed policy should not discriminate");
    assert!(
        aimd.total_timed_out() <= fixed.total_timed_out(),
        "aimd lost more uploads than fixed on the same seed: {} vs {}",
        aimd.total_timed_out(),
        fixed.total_timed_out()
    );
}
