//! Scalar-vs-SIMD parity for the dispatched kernel layer (DESIGN.md §8).
//!
//! Runs against whatever level this process dispatches at (CPU
//! detection or `QRR_SIMD`): under the CI `QRR_SIMD=scalar` gate this
//! pins the portable fallback; on AVX2 hardware it pins the vector
//! kernels. Elementwise float kernels and the fused LAQ pass must be
//! **bit-exact** against the scalar oracle, integer packers
//! **byte-for-byte**; `dot` and the GEMM tile agree within tolerance.

use qrr::exec::simd;
use qrr::linalg::{matmul, matmul_nt, matmul_tn};
use qrr::quant::{dequantize, pack_codes, packed_len_bytes, quantize, unpack_codes};
use qrr::util::Rng;
use qrr::Tensor;

/// Lengths straddling the 8-lane width, the 4-lane f64 width and every
/// remainder boundary.
const LENS: [usize; 14] = [0, 1, 3, 4, 5, 7, 8, 9, 15, 16, 17, 33, 100, 1037];

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.range_f32(-2.0, 2.0)).collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn elementwise_kernels_bit_exact_vs_scalar_oracle() {
    let mut rng = Rng::new(0xD15);
    for &n in &LENS {
        for alpha in [0.37f32, -1.0, 1.0, 0.0] {
            let x = rand_vec(&mut rng, n);
            let y = rand_vec(&mut rng, n);

            let mut got = y.clone();
            simd::axpy(&mut got, alpha, &x);
            let mut want = y.clone();
            simd::scalar::axpy(&mut want, alpha, &x);
            assert_eq!(bits(&got), bits(&want), "axpy n={n} alpha={alpha}");

            let mut got = y.clone();
            simd::sum_into(&mut got, &x);
            let mut want = y.clone();
            simd::scalar::sum_into(&mut want, &x);
            assert_eq!(bits(&got), bits(&want), "sum_into n={n}");

            let mut got = y.clone();
            simd::scale(&mut got, alpha);
            let mut want = y.clone();
            simd::scalar::scale(&mut want, alpha);
            assert_eq!(bits(&got), bits(&want), "scale n={n} alpha={alpha}");

            let mut got = y.clone();
            simd::mul(&mut got, &x);
            let mut want = y.clone();
            simd::scalar::mul(&mut want, &x);
            assert_eq!(bits(&got), bits(&want), "mul n={n}");

            assert_eq!(
                simd::max_abs(&x).to_bits(),
                simd::scalar::max_abs(&x).to_bits(),
                "max_abs n={n}"
            );
            assert_eq!(
                simd::max_abs_diff(&x, &y).to_bits(),
                simd::scalar::max_abs_diff(&x, &y).to_bits(),
                "max_abs_diff n={n}"
            );
        }
    }
}

#[test]
fn dot_matches_scalar_within_tolerance() {
    let mut rng = Rng::new(0xD07);
    for &n in &LENS {
        let x = rand_vec(&mut rng, n);
        let y = rand_vec(&mut rng, n);
        let got = simd::dot(&x, &y);
        let want = simd::scalar::dot(&x, &y);
        assert!(
            (got - want).abs() <= 1e-4 * want.abs().max(1.0),
            "dot n={n}: {got} vs {want}"
        );
        // and against a slow f64 reference
        let exact: f64 = x.iter().zip(y.iter()).map(|(&a, &b)| a as f64 * b as f64).sum();
        assert!(
            (got as f64 - exact).abs() <= 1e-3 * exact.abs().max(1.0),
            "dot n={n}: {got} vs exact {exact}"
        );
    }
}

#[test]
fn laq_fused_pass_bit_exact_vs_scalar_oracle() {
    let mut rng = Rng::new(0x1A0);
    for &n in &LENS {
        for beta in 1..=16u8 {
            let g = rand_vec(&mut rng, n);
            let prev = rand_vec(&mut rng, n);
            let radius = simd::scalar::max_abs_diff(&g, &prev);
            if radius == 0.0 {
                continue;
            }
            let mut codes = vec![0u32; n];
            let mut out = vec![0f32; n];
            simd::laq_quantize(&g, &prev, radius, beta, &mut codes, &mut out);
            let mut codes_s = vec![0u32; n];
            let mut out_s = vec![0f32; n];
            simd::scalar::laq_quantize(&g, &prev, radius, beta, &mut codes_s, &mut out_s);
            assert_eq!(codes, codes_s, "codes n={n} beta={beta}");
            assert_eq!(bits(&out), bits(&out_s), "recon n={n} beta={beta}");

            let mut dec = vec![0f32; n];
            simd::laq_dequantize(&codes, &prev, radius, beta, &mut dec);
            assert_eq!(bits(&dec), bits(&out), "dequant n={n} beta={beta}");
        }
    }
}

#[test]
fn bitpack_byte_for_byte_all_betas_adversarial_lengths() {
    // byte-at-a-time reference, independent of the crate's packers
    fn ref_pack(codes: &[u32], beta: u8) -> Vec<u8> {
        let mut out = vec![0u8; packed_len_bytes(codes.len(), beta)];
        let mut bitpos = 0usize;
        for &c in codes {
            let merged = (c as u64) << (bitpos % 8);
            let byte = bitpos / 8;
            out[byte] |= (merged & 0xFF) as u8;
            if bitpos % 8 + beta as usize > 8 {
                out[byte + 1] |= ((merged >> 8) & 0xFF) as u8;
            }
            if bitpos % 8 + beta as usize > 16 {
                out[byte + 2] |= ((merged >> 16) & 0xFF) as u8;
            }
            bitpos += beta as usize;
        }
        out
    }
    let mut rng = Rng::new(0xB17);
    for beta in 1..=16u8 {
        let max = (1u64 << beta) as usize;
        for &n in &LENS {
            let codes: Vec<u32> = (0..n).map(|_| rng.below(max) as u32).collect();
            let packed = pack_codes(&codes, beta);
            assert_eq!(packed.len(), packed_len_bytes(n, beta), "len beta={beta} n={n}");
            assert_eq!(packed, ref_pack(&codes, beta), "pack beta={beta} n={n}");
            assert_eq!(unpack_codes(&packed, n, beta), codes, "unpack beta={beta} n={n}");
        }
    }
}

#[test]
fn quantizer_wire_bytes_deterministic_and_within_bound() {
    // end to end through the public quantizer: the paper's eq. (18)
    // bound holds and repeated encodes of the same input produce
    // identical wire bytes (process-global dispatch)
    let mut rng = Rng::new(0x0E8);
    for &n in &[1usize, 7, 63, 64, 65, 1037] {
        for beta in [1u8, 2, 4, 8, 16] {
            let g = Tensor::randn(&[n], &mut rng);
            let prev = Tensor::randn(&[n], &mut rng);
            let (msg, q) = quantize(&g, &prev, beta);
            let (msg2, _) = quantize(&g, &prev, beta);
            assert_eq!(msg, msg2, "non-deterministic encode n={n} beta={beta}");
            let tau = 1.0 / ((1u32 << beta) - 1) as f32;
            let bound = tau * msg.radius * (1.0 + 1e-4) + 1e-7;
            assert!(
                g.sub(&q).max_norm() <= bound,
                "eq18 n={n} beta={beta}: {} > {bound}",
                g.sub(&q).max_norm()
            );
            // the server-side reconstruction agrees with the client's
            let back = dequantize(&msg, &prev);
            assert_eq!(bits(q.data()), bits(back.data()), "n={n} beta={beta}");
        }
    }
}

#[test]
fn gemm_dispatch_matches_naive_on_adversarial_shapes() {
    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let n = b.shape()[1];
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f64;
                for kk in 0..k {
                    acc += a.get2(i, kk) as f64 * b.get2(kk, j) as f64;
                }
                c.set2(i, j, acc as f32);
            }
        }
        c
    }
    let mut rng = Rng::new(0x6E0);
    for &(m, k, n) in &[
        (1usize, 1usize, 1usize),
        (8, 8, 8),
        (9, 7, 9),
        (7, 300, 5),
        (65, 129, 67),
        (1, 9, 1),
    ] {
        let a = Tensor::randn(&[m, k], &mut rng);
        let b = Tensor::randn(&[k, n], &mut rng);
        let want = naive(&a, &b);
        assert!(matmul(&a, &b).rel_err(&want) < 1e-4, "{m}x{k}x{n}");
        assert!(
            matmul_tn(&a.transpose(), &b).rel_err(&want) < 1e-4,
            "tn {m}x{k}x{n}"
        );
        assert!(
            matmul_nt(&a, &b.transpose()).rel_err(&want) < 1e-4,
            "nt {m}x{k}x{n}"
        );
    }
}
