//! Property-based sweeps over the session-side invariants (the
//! proptest substitute — `qrr::testing::prop`): quantizer bounds,
//! codec synchronization, wire round-trips, rank rules, tensor algebra.

use qrr::compress::{
    compress_svd, compress_tucker, decompress_svd, decompress_tucker, svd_is_smaller, svd_rank,
    tucker_is_smaller, tucker_ranks,
};
use qrr::linalg::{
    gemm_acc, gemm_acc_nt, gemm_acc_tn, matmul, matmul_nt, matmul_tn, qr_thin, qr_thin_unblocked,
    SvdMethod,
};
use qrr::net::{ClientUpdate, Decoder, Encoder};
use qrr::qrr::{ClientCodec, QrrConfig, ServerCodec};
use qrr::quant::{dequantize, quantize, QuantState};
use qrr::tensor::{fold, mode_n_product, unfold, Tensor};
use qrr::testing::forall;

#[test]
fn prop_quantize_error_bound_eq18() {
    forall(
        0xA1,
        80,
        |g| {
            let beta = *g.choose(&[1u8, 2, 4, 8, 12]);
            let n = g.usize_in(1, 400);
            let x = Tensor::randn(&[n], g.rng());
            let prev = Tensor::randn(&[n], g.rng());
            (x, prev, beta)
        },
        |(x, prev, beta)| {
            let (msg, q) = quantize(&x, &prev, beta);
            let tau = 1.0 / ((1u32 << beta) - 1) as f32;
            let bound = tau * msg.radius * (1.0 + 1e-4) + 1e-7;
            assert!(x.sub(&q).max_norm() <= bound);
        },
    );
}

#[test]
fn prop_quantize_dequantize_server_client_agree() {
    forall(
        0xA2,
        60,
        |g| {
            let n = g.usize_in(1, 300);
            let beta = *g.choose(&[4u8, 8]);
            let rounds = g.usize_in(1, 6);
            let tensors: Vec<Tensor> =
                (0..rounds).map(|_| Tensor::randn(&[n], g.rng())).collect();
            (tensors, beta)
        },
        |(tensors, beta)| {
            let shape = tensors[0].shape().to_vec();
            let mut client = QuantState::zeros(&shape);
            let mut prev_server = Tensor::zeros(&shape);
            for t in &tensors {
                let msg = client.quantize_update(t, beta);
                let server_val = dequantize(&msg, &prev_server);
                assert!(client.value().sub(&server_val).max_norm() < 1e-5);
                prev_server = server_val;
            }
        },
    );
}

#[test]
fn prop_svd_compress_decompress_shape_and_bound() {
    forall(
        0xA3,
        40,
        |g| {
            let m = g.usize_in(2, 40);
            let n = g.usize_in(2, 40);
            let p = g.f32_in(0.05, 1.0) as f64;
            (Tensor::randn(&[m, n], g.rng()), p)
        },
        |(x, p)| {
            let (m, n) = (x.shape()[0], x.shape()[1]);
            let nu = svd_rank(m, n, p);
            assert!(nu >= 1 && nu <= m.min(n));
            let c = compress_svd(&x, nu, SvdMethod::Jacobi);
            let rec = decompress_svd(&c);
            assert_eq!(rec.shape(), x.shape());
            // projection never exceeds the original energy (up to fp noise)
            assert!(rec.fro_norm() <= x.fro_norm() * 1.01);
            // full rank reconstructs
            if nu == m.min(n) {
                assert!(x.rel_err(&rec) < 1e-3);
            }
        },
    );
}

#[test]
fn prop_tucker_roundtrip_all_modes() {
    forall(
        0xA4,
        25,
        |g| {
            let dims: Vec<usize> = (0..4).map(|_| g.usize_in(2, 8)).collect();
            let p = g.f32_in(0.2, 1.0) as f64;
            (Tensor::randn(&dims, g.rng()), p)
        },
        |(x, p)| {
            let ranks = tucker_ranks(x.shape(), p);
            let c = compress_tucker(&x, &ranks, SvdMethod::Jacobi);
            let rec = decompress_tucker(&c);
            assert_eq!(rec.shape(), x.shape());
            assert!(rec.fro_norm() <= x.fro_norm() * 1.01);
        },
    );
}

#[test]
fn prop_unfold_fold_inverse() {
    forall(
        0xA5,
        50,
        |g| {
            let ndim = g.usize_in(2, 5);
            let t = g.tensor(ndim, 6);
            let mode = g.usize_in(0, ndim - 1);
            (t, mode)
        },
        |(t, mode)| {
            let u = unfold(&t, mode);
            let back = fold(&u, mode, t.shape());
            assert_eq!(t, back);
        },
    );
}

#[test]
fn prop_mode_product_shape_rule() {
    forall(
        0xA6,
        40,
        |g| {
            let t = g.tensor(3, 6);
            let mode = g.usize_in(0, 2);
            let j = g.usize_in(1, 7);
            let f = Tensor::randn(&[j, t.shape()[mode]], g.rng());
            (t, mode, f)
        },
        |(t, mode, f)| {
            let y = mode_n_product(&t, mode, &f);
            let mut expect = t.shape().to_vec();
            expect[mode] = f.shape()[0];
            assert_eq!(y.shape(), &expect[..]);
        },
    );
}

#[test]
fn prop_wire_roundtrip_any_qrr_message() {
    forall(
        0xA7,
        30,
        |g| {
            let n_params = g.usize_in(1, 4);
            let mut shapes = Vec::new();
            for _ in 0..n_params {
                let kind = g.usize_in(0, 2);
                shapes.push(match kind {
                    0 => vec![g.usize_in(2, 20), g.usize_in(2, 20)],
                    1 => vec![g.usize_in(1, 50)],
                    _ => vec![
                        g.usize_in(2, 6),
                        g.usize_in(2, 6),
                        g.usize_in(2, 4),
                        g.usize_in(2, 4),
                    ],
                });
            }
            let p = g.f32_in(0.1, 0.9) as f64;
            let grads: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, g.rng())).collect();
            (shapes, grads, p)
        },
        |(shapes, grads, p)| {
            let mut codec = ClientCodec::new(&shapes, QrrConfig::with_p(p));
            let msgs = codec.encode(&grads);
            let up = ClientUpdate::Qrr { msgs };
            let bytes = Encoder::new(&up, 7, 3);
            let dec = Decoder::decode(&bytes).unwrap();
            assert_eq!(dec.client_id, 7);
            assert_eq!(dec.round, 3);
            assert_eq!(dec.update.payload_bits(), up.payload_bits());
        },
    );
}

#[test]
fn prop_client_server_codec_lockstep() {
    forall(
        0xA8,
        20,
        |g| {
            let shapes = vec![
                vec![g.usize_in(3, 15), g.usize_in(3, 15)],
                vec![g.usize_in(1, 20)],
            ];
            let p = g.f32_in(0.2, 1.0) as f64;
            let rounds = g.usize_in(1, 5);
            let grads: Vec<Vec<Tensor>> = (0..rounds)
                .map(|_| shapes.iter().map(|s| Tensor::randn(s, g.rng())).collect())
                .collect();
            (shapes, grads, p)
        },
        |(shapes, grads, p)| {
            let cfg = QrrConfig::with_p(p);
            let mut client = ClientCodec::new(&shapes, cfg);
            let mut server = ServerCodec::new(&shapes, cfg);
            for round_grads in &grads {
                let msgs = client.encode(round_grads);
                let _ = server.decode(&msgs);
                for (cs, ss) in client.states().iter().zip(server.states().iter()) {
                    assert!(cs.states_close(ss, 1e-5));
                }
            }
        },
    );
}

#[test]
fn prop_size_inequalities_hold_for_small_p() {
    // paper: "we typically want p < 0.5" for (8)/(11) to hold
    forall(
        0xA9,
        60,
        |g| {
            let m = g.usize_in(16, 256);
            let n = g.usize_in(16, 1024);
            let dims: Vec<usize> = vec![
                g.usize_in(8, 64),
                g.usize_in(8, 64),
                g.usize_in(3, 5),
                g.usize_in(3, 5),
            ];
            let p = g.f32_in(0.05, 0.35) as f64;
            (m, n, dims, p)
        },
        |(m, n, dims, p)| {
            let nu = svd_rank(m, n, p);
            assert!(svd_is_smaller(m, n, nu), "SVD ineq fails: {m}x{n} nu={nu}");
            let ranks = tucker_ranks(&dims, p);
            assert!(
                tucker_is_smaller(&dims, &ranks),
                "Tucker ineq fails: {dims:?} {ranks:?}"
            );
        },
    );
}

#[test]
fn prop_payload_bits_formula() {
    // QRR payload == sum over factors of (32 + beta * elems)
    forall(
        0xAA,
        30,
        |g| {
            let m = g.usize_in(4, 30);
            let n = g.usize_in(4, 30);
            let p = g.f32_in(0.1, 0.9) as f64;
            (Tensor::randn(&[m, n], g.rng()), p)
        },
        |(x, p)| {
            let (m, n) = (x.shape()[0], x.shape()[1]);
            let shapes = vec![vec![m, n]];
            let cfg = QrrConfig::with_p(p);
            let mut codec = ClientCodec::new(&shapes, cfg);
            let msgs = codec.encode(&[x]);
            let nu = svd_rank(m, n, p);
            let expect = (32 + 8 * (m * nu) as u64)
                + (32 + 8 * nu as u64)
                + (32 + 8 * (n * nu) as u64);
            assert_eq!(msgs[0].wire_bits(), expect);
        },
    );
}

// ------------------------------------------------------- packed GEMM

/// f64-accumulated reference product.
fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let n = b.shape()[1];
    let mut c = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f64;
            for kk in 0..k {
                acc += a.get2(i, kk) as f64 * b.get2(kk, j) as f64;
            }
            c.set2(i, j, acc as f32);
        }
    }
    c
}

#[test]
fn prop_packed_gemm_matches_naive_all_variants() {
    // adversarial shapes: off-tile sizes, m=1 / n=1 / k=1 strips, and
    // the empty k=0 product, across all four transpose variants plus
    // the accumulate entries
    forall(
        0xB1,
        40,
        |g| {
            let dims = [0usize, 1, 2, 3, 5, 7, 8, 9, 15, 16, 17, 31, 33, 65];
            let m = *g.choose(&dims[1..]);
            let k = *g.choose(&dims);
            let n = *g.choose(&dims[1..]);
            (
                Tensor::randn(&[m, k], g.rng()),
                Tensor::randn(&[k, n], g.rng()),
                Tensor::randn(&[m, n], g.rng()),
            )
        },
        |(a, b, c0)| {
            let want = naive_matmul(&a, &b);
            let tol = 1e-4 * (1.0 + want.max_norm());
            assert!(matmul(&a, &b).sub(&want).max_norm() <= tol);
            assert!(matmul_tn(&a.transpose(), &b).sub(&want).max_norm() <= tol);
            assert!(matmul_nt(&a, &b.transpose()).sub(&want).max_norm() <= tol);

            let want_acc = c0.add(&want);
            let mut c = c0.clone();
            gemm_acc(&mut c, &a, &b);
            assert!(c.sub(&want_acc).max_norm() <= tol);
            let mut c = c0.clone();
            gemm_acc_tn(&mut c, &a.transpose(), &b);
            assert!(c.sub(&want_acc).max_norm() <= tol);
            let mut c = c0.clone();
            gemm_acc_nt(&mut c, &a, &b.transpose());
            assert!(c.sub(&want_acc).max_norm() <= tol);
        },
    );
}

#[test]
fn prop_blocked_qr_parity_with_scalar_path() {
    // the blocked compact-WY factorization uses the scalar path's sign
    // convention, so Q and R agree directly (to fp reordering), and the
    // usual QR invariants hold
    forall(
        0xB2,
        20,
        |g| {
            let n = g.usize_in(1, 40);
            let m = n + g.usize_in(0, 60);
            Tensor::randn(&[m, n], g.rng())
        },
        |a| {
            let n = a.shape()[1];
            let blk = qr_thin(&a);
            let scl = qr_thin_unblocked(&a);
            assert!(blk.r.rel_err(&scl.r) < 1e-3, "R err {}", blk.r.rel_err(&scl.r));
            assert!(blk.q.rel_err(&scl.q) < 1e-3, "Q err {}", blk.q.rel_err(&scl.q));
            let qtq = matmul_tn(&blk.q, &blk.q);
            assert!(qtq.rel_err(&Tensor::eye(n)) < 1e-3);
            let rec = matmul(&blk.q, &blk.r);
            assert!(a.rel_err(&rec) < 1e-3);
        },
    );
}
