//! Integration: the PJRT/HLO path must agree numerically with the
//! pure-Rust reference backend, and the standalone kernel artifacts must
//! agree with the Rust quant/linalg engines.
//!
//! These tests need `make artifacts` (at least
//! `python -m compile.aot --models mlp --batches 32 --quick`); when no
//! manifest is present they are skipped so plain `cargo test` stays
//! green before the python build step.

use qrr::model::{native::NativeModel, ModelKind, ModelOps, ModelSpec};
use qrr::runtime::{artifacts_dir, Manifest, PjrtEngine, PjrtModel};
use qrr::tensor::Tensor;
use qrr::util::Rng;

fn manifest() -> Option<Manifest> {
    Manifest::load(&artifacts_dir()).ok()
}

fn batch(spec: &ModelSpec, n: usize, seed: u64) -> (Tensor, Vec<u32>) {
    let mut rng = Rng::new(seed);
    let mut x = Tensor::randn(&[n, spec.input_dim()], &mut rng);
    // inputs in [0,1] like pixels
    for v in x.data_mut() {
        *v = (*v * 0.25 + 0.5).clamp(0.0, 1.0);
    }
    let y = (0..n).map(|i| (i % 10) as u32).collect();
    (x, y)
}

#[test]
fn mlp_grad_parity_native_vs_pjrt() {
    let Some(m) = manifest() else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    };
    if m.for_model_fn("mlp", "grad").is_empty() {
        eprintln!("skipping: no mlp grad artifact");
        return;
    }
    let engine = PjrtEngine::start(m.clone()).unwrap();
    let pjrt = PjrtModel::new(ModelKind::Mlp, m, engine).unwrap();
    let native = NativeModel::new(ModelKind::Mlp);
    let spec = ModelSpec::new(ModelKind::Mlp);
    let params = spec.init_params(5);
    let (x, y) = batch(&spec, 8, 6);

    let (l_n, g_n) = native.loss_grad(&params, &x, &y);
    let (l_p, g_p) = pjrt.loss_grad(&params, &x, &y);
    assert!(
        (l_n - l_p).abs() / l_n.abs().max(1e-6) < 1e-3,
        "loss mismatch: native {l_n} pjrt {l_p}"
    );
    for (i, (a, b)) in g_n.iter().zip(g_p.iter()).enumerate() {
        assert_eq!(a.shape(), b.shape(), "param {i} shape");
        assert!(
            a.rel_err(b) < 1e-2,
            "param {i} ({}) grad mismatch: rel err {}",
            spec.params[i].name,
            a.rel_err(b)
        );
    }
}

#[test]
fn mlp_eval_parity_and_padding() {
    let Some(m) = manifest() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    if m.for_model_fn("mlp", "eval").is_empty() {
        eprintln!("skipping: no mlp eval artifact");
        return;
    }
    let engine = PjrtEngine::start(m.clone()).unwrap();
    let pjrt = PjrtModel::new(ModelKind::Mlp, m, engine).unwrap();
    let native = NativeModel::new(ModelKind::Mlp);
    let spec = ModelSpec::new(ModelKind::Mlp);
    let params = spec.init_params(7);
    // batch 13 (not a multiple of the artifact's 32): exercises padding
    let (x, y) = batch(&spec, 13, 8);
    let (l_n, c_n) = native.eval(&params, &x, &y);
    let (l_p, c_p) = pjrt.eval(&params, &x, &y);
    assert!(
        (l_n - l_p).abs() / l_n.abs().max(1e-6) < 1e-3,
        "eval loss mismatch: {l_n} vs {l_p}"
    );
    assert_eq!(c_n, c_p, "correct-count mismatch");
}

#[test]
fn mlp_grad_chunking_matches_single_batch() {
    // batch 70 with a b32 artifact: 3 chunks; weighted combination must
    // equal the mean gradient over all 70 rows.
    let Some(m) = manifest() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    if m.for_model_fn("mlp", "grad").is_empty() {
        eprintln!("skipping: no mlp grad artifact");
        return;
    }
    let engine = PjrtEngine::start(m.clone()).unwrap();
    let pjrt = PjrtModel::new(ModelKind::Mlp, m, engine).unwrap();
    let native = NativeModel::new(ModelKind::Mlp);
    let spec = ModelSpec::new(ModelKind::Mlp);
    let params = spec.init_params(9);
    let (x, y) = batch(&spec, 70, 10);
    let (l_n, g_n) = native.loss_grad(&params, &x, &y);
    let (l_p, g_p) = pjrt.loss_grad(&params, &x, &y);
    assert!((l_n - l_p).abs() / l_n.abs().max(1e-6) < 1e-3);
    for (a, b) in g_n.iter().zip(g_p.iter()) {
        assert!(a.rel_err(b) < 1e-2, "rel err {}", a.rel_err(b));
    }
}

#[test]
fn quantize_artifact_matches_rust_quantizer() {
    let Some(m) = manifest() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    if m.by_name("quantize_16384").is_none() {
        eprintln!("skipping: no quantize artifact");
        return;
    }
    let engine = PjrtEngine::start(m).unwrap();
    let mut rng = Rng::new(11);
    let n = 16384usize;
    let g = Tensor::randn(&[n], &mut rng);
    let prev = Tensor::randn(&[n], &mut rng);
    let outs = engine
        .execute(
            "quantize_16384",
            vec![
                (vec![n], g.data().to_vec()),
                (vec![n], prev.data().to_vec()),
            ],
        )
        .unwrap();
    // outputs: radius, codes, new_val
    let radius = outs[0].1[0];
    let codes = &outs[1].1;
    let val = &outs[2].1;

    let (q, new_val) = qrr::quant::quantize(&g, &prev, 8);
    assert!(
        (radius - q.radius).abs() / q.radius.max(1e-9) < 1e-5,
        "radius {radius} vs {}",
        q.radius
    );
    let rust_codes = q.codes();
    let mut code_mismatch = 0usize;
    for (a, b) in codes.iter().zip(rust_codes.iter()) {
        if (*a - *b as f32).abs() > 0.5 {
            code_mismatch += 1;
        }
    }
    // floor() at exact grid boundaries may differ by 1 ulp between
    // implementations; allow a whisker of disagreement
    assert!(
        code_mismatch < n / 1000,
        "too many code mismatches: {code_mismatch}"
    );
    let pjrt_val = Tensor::from_vec(&[n], val.clone());
    assert!(
        new_val.rel_err(&pjrt_val) < 1e-3,
        "dequantized values differ: {}",
        new_val.rel_err(&pjrt_val)
    );
}

#[test]
fn rangefinder_artifact_is_a_gemm() {
    let Some(m) = manifest() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    if m.by_name("rangefinder_256x192_l24").is_none() {
        eprintln!("skipping: no rangefinder artifact");
        return;
    }
    let engine = PjrtEngine::start(m).unwrap();
    let mut rng = Rng::new(12);
    let a = Tensor::randn(&[256, 192], &mut rng);
    let omega = Tensor::randn(&[192, 24], &mut rng);
    let outs = engine
        .execute(
            "rangefinder_256x192_l24",
            vec![
                (vec![256, 192], a.data().to_vec()),
                (vec![192, 24], omega.data().to_vec()),
            ],
        )
        .unwrap();
    let y = Tensor::from_vec(&[256, 24], outs[0].1.clone());
    let expect = qrr::linalg::matmul(&a, &omega);
    assert!(expect.rel_err(&y) < 1e-4, "rel err {}", expect.rel_err(&y));
}
