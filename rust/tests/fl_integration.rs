//! End-to-end FL integration: full rounds across schemes, wire decode at
//! the server, metric invariants, link simulation and failure handling.

use qrr::compress::pipeline::PipelineSpec;
use qrr::config::{ExperimentConfig, PPolicy, SchemeConfig};
use qrr::data::DatasetKind;
use qrr::fl::session::{FlSessionBuilder, RunReport};
use qrr::model::ModelKind;

/// Run a config through the session builder, every seam at its default.
fn run(cfg: &ExperimentConfig) -> RunReport {
    FlSessionBuilder::new(cfg).quiet().build().unwrap().run().unwrap()
}

fn tiny(scheme: SchemeConfig, model: ModelKind, dataset: DatasetKind) -> ExperimentConfig {
    let mut c = ExperimentConfig::table1_default();
    c.scheme = scheme;
    c.model = model;
    c.dataset = dataset;
    c.clients = 3;
    c.iters = 8;
    c.batch = 12;
    c.train_n = 240;
    c.test_n = 60;
    c.eval_every = 4;
    c.lr_schedule = vec![(0, 0.05)];
    c
}

#[test]
fn all_schemes_learn_on_mlp() {
    for scheme in [
        SchemeConfig::Sgd,
        SchemeConfig::Slaq,
        SchemeConfig::Qrr(PPolicy::Fixed(0.3)),
    ] {
        let cfg = tiny(scheme, ModelKind::Mlp, DatasetKind::Mnist);
        let report = run(&cfg);
        let h = &report.history;
        let first = h.evals.first().unwrap();
        let last = h.evals.last().unwrap();
        assert!(
            last.loss < first.loss,
            "{}: no learning {} -> {}",
            scheme.label(),
            first.loss,
            last.loss
        );
        assert!(last.accuracy > 0.15, "{}: acc {}", scheme.label(), last.accuracy);
    }
}

#[test]
fn cnn_round_with_tucker_compression() {
    // conv gradients go through the Tucker path end to end
    let cfg = tiny(
        SchemeConfig::Qrr(PPolicy::Fixed(0.3)),
        ModelKind::Cnn,
        DatasetKind::Mnist,
    );
    let report = run(&cfg);
    assert!(report.history.evals.last().unwrap().loss.is_finite());
    // CNN: QRR bits must be far under SGD's 32 bits/param
    let dense_bits = 3 * 8 * qrr::model::ModelSpec::new(ModelKind::Cnn).num_params() as u64 * 32;
    assert!(report.history.total_bits() < dense_bits / 4);
}

#[test]
fn vgg_adaptive_p_runs() {
    let mut cfg = tiny(
        SchemeConfig::Qrr(PPolicy::Adaptive { lo: 0.1, hi: 0.3 }),
        ModelKind::Vgg,
        DatasetKind::Cifar10,
    );
    cfg.iters = 3;
    cfg.batch = 8;
    cfg.train_n = 90;
    cfg.test_n = 30;
    cfg.eval_every = 3;
    let report = run(&cfg);
    assert_eq!(report.history.iterations(), 3);
    assert!(report.history.total_bits() > 0);
}

#[test]
fn bit_ordering_matches_paper_qrr_lt_slaq_lt_sgd() {
    let bits = |scheme| {
        let cfg = tiny(scheme, ModelKind::Mlp, DatasetKind::Mnist);
        run(&cfg).history.total_bits()
    };
    let sgd = bits(SchemeConfig::Sgd);
    let slaq = bits(SchemeConfig::Slaq);
    let qrr01 = bits(SchemeConfig::Qrr(PPolicy::Fixed(0.1)));
    let qrr03 = bits(SchemeConfig::Qrr(PPolicy::Fixed(0.3)));
    assert!(slaq <= sgd / 3, "slaq {slaq} vs sgd {sgd}");
    assert!(qrr03 < slaq, "qrr03 {qrr03} vs slaq {slaq}");
    assert!(qrr01 < qrr03, "qrr01 {qrr01} vs qrr03 {qrr03}");
    // paper's headline: QRR(0.1) ~3% of SGD
    let frac = qrr01 as f64 / sgd as f64;
    assert!(frac < 0.10, "QRR(0.1) used {:.1}% of SGD bits", 100.0 * frac);
}

#[test]
fn comms_counted_per_upload() {
    let cfg = tiny(SchemeConfig::Sgd, ModelKind::Mlp, DatasetKind::Mnist);
    let h = run(&cfg).history;
    // SGD never skips: comms == clients * iters
    assert_eq!(h.total_comms(), 3 * 8);
    // SLAQ may skip but never exceeds
    let cfg = tiny(SchemeConfig::Slaq, ModelKind::Mlp, DatasetKind::Mnist);
    let h = run(&cfg).history;
    assert!(h.total_comms() <= 24);
    assert!(h.total_comms() >= 3); // at least the first round
}

#[test]
fn net_time_reflects_link_speeds() {
    // slower links -> more simulated network time for the same bits
    let mut fast = tiny(SchemeConfig::Sgd, ModelKind::Mlp, DatasetKind::Mnist);
    fast.link_slow_bps = 1e9;
    fast.link_fast_bps = 1e9;
    let mut slow = fast.clone();
    slow.link_slow_bps = 1e5;
    slow.link_fast_bps = 1e5;
    let t_fast = run(&fast).history.total_net_time();
    let t_slow = run(&slow).history.total_net_time();
    assert!(t_slow > t_fast * 100, "{t_slow:?} vs {t_fast:?}");
}

#[test]
fn qrr_survives_quiet_gradient_rounds() {
    // a round of exactly-zero gradients (radius == 0) must not poison
    // the codec state
    use qrr::qrr::{ClientCodec, QrrConfig, ServerCodec};
    use qrr::tensor::Tensor;
    use qrr::util::Rng;
    let shapes = vec![vec![20, 30], vec![20]];
    let cfg = QrrConfig::with_p(0.3);
    let mut client = ClientCodec::new(&shapes, cfg);
    let mut server = ServerCodec::new(&shapes, cfg);
    let mut rng = Rng::new(55);
    for round in 0..6 {
        let scale = if round == 3 { 0.0 } else { 1.0 };
        let grads: Vec<Tensor> = shapes
            .iter()
            .map(|s| {
                let mut t = Tensor::randn(s, &mut rng);
                t.scale(scale);
                t
            })
            .collect();
        let rec = server.decode(&client.encode(&grads));
        for r in &rec {
            assert!(r.fro_norm().is_finite(), "non-finite at round {round}");
        }
    }
}

#[test]
fn run_report_markdown_has_paper_columns() {
    let cfg = tiny(SchemeConfig::Qrr(PPolicy::Fixed(0.2)), ModelKind::Mlp, DatasetKind::Mnist);
    let report = run(&cfg);
    let md = report.markdown_table();
    for col in ["Algorithm", "# Iterations", "# Bits", "# Communications", "Loss", "Accuracy"] {
        assert!(md.contains(col), "missing column {col}: {md}");
    }
    assert!(md.contains("QRR(p=0.2)"));
}

#[test]
fn per_round_train_loss_trends_down_under_sgd() {
    let mut cfg = tiny(SchemeConfig::Sgd, ModelKind::Mlp, DatasetKind::Mnist);
    cfg.iters = 20;
    let h = run(&cfg).history;
    let head: f64 = h.rounds[..5].iter().map(|r| r.train_loss as f64).sum::<f64>() / 5.0;
    let tail: f64 = h.rounds[15..].iter().map(|r| r.train_loss as f64).sum::<f64>() / 5.0;
    assert!(tail < head, "train loss head {head} tail {tail}");
}

// ---------------------------------------------------------- extensions

#[test]
fn ef_qrr_trains_stably_at_tiny_p() {
    // End-to-end stability of the error-feedback variant at aggressive
    // compression. (The strict bias-removal property is proven at unit
    // level in qrr::error_feedback::tests — over a short noisy run EF and
    // plain QRR trade places, so here we check learning + sane loss.)
    let train = |scheme| {
        let mut cfg = tiny(scheme, ModelKind::Mlp, DatasetKind::Mnist);
        cfg.iters = 15;
        cfg.lr_schedule = vec![(0, 0.02)];
        let h = run(&cfg).history;
        (h.evals.first().unwrap().loss, h.evals.last().unwrap().loss)
    };
    let (plain_first, plain_last) = train(SchemeConfig::Qrr(PPolicy::Fixed(0.05)));
    let (ef_first, ef_last) = train(SchemeConfig::QrrEf(PPolicy::Fixed(0.05)));
    assert!(plain_last < plain_first, "plain QRR no learning");
    assert!(ef_last < ef_first, "EF-QRR no learning");
    assert!(
        ef_last < plain_last * 1.5,
        "EF-QRR unstable: plain {plain_last} ef {ef_last}"
    );
}

#[test]
fn ef_qrr_same_wire_bits_as_plain() {
    let bits = |scheme| {
        let cfg = tiny(scheme, ModelKind::Mlp, DatasetKind::Mnist);
        run(&cfg).history.total_bits()
    };
    assert_eq!(
        bits(SchemeConfig::Qrr(PPolicy::Fixed(0.2))),
        bits(SchemeConfig::QrrEf(PPolicy::Fixed(0.2)))
    );
}

#[test]
fn non_iid_sharding_still_learns() {
    use qrr::config::Sharding;
    for sharding in [Sharding::LabelSkew(2), Sharding::Dirichlet(0.5)] {
        let mut cfg = tiny(SchemeConfig::Qrr(PPolicy::Fixed(0.3)), ModelKind::Mlp, DatasetKind::Mnist);
        cfg.sharding = sharding;
        cfg.iters = 12;
        let h = run(&cfg).history;
        let first = h.evals.first().unwrap().loss;
        let last = h.evals.last().unwrap().loss;
        assert!(last < first, "{sharding:?}: {first} -> {last}");
    }
}

#[test]
fn partial_participation_reduces_comms_proportionally() {
    use qrr::config::ParticipationConfig;
    let mut cfg = tiny(SchemeConfig::Qrr(PPolicy::Fixed(0.2)), ModelKind::Mlp, DatasetKind::Mnist);
    cfg.clients = 4;
    cfg.participation = ParticipationConfig::Uniform { fraction: 0.5 };
    cfg.iters = 10;
    let h = run(&cfg).history;
    // ceil(0.5*4)=2 participants per round
    assert_eq!(h.total_comms(), 2 * 10);
    assert!(h.evals.last().unwrap().loss.is_finite());
}

#[test]
fn adaptive_p_assigns_different_ranks() {
    // migrated from the retired coordinator shim: per-client adaptive p
    // must produce different factor-state sizes per link speed
    let cfg = tiny(
        SchemeConfig::Qrr(PPolicy::Adaptive { lo: 0.1, hi: 0.3 }),
        ModelKind::Mlp,
        DatasetKind::Mnist,
    );
    let session = FlSessionBuilder::new(&cfg).quiet().build().unwrap();
    let mems: Vec<usize> = session
        .clients()
        .iter()
        .map(|c| c.scheme_mem_bytes())
        .collect();
    assert!(mems.windows(2).any(|w| w[0] != w[1]), "mems {mems:?}");
}

#[test]
fn lr_schedule_transitions_mid_run() {
    // migrated from the retired coordinator shim
    let mut cfg = tiny(SchemeConfig::Sgd, ModelKind::Mlp, DatasetKind::Mnist);
    cfg.lr_schedule = vec![(0, 0.05), (3, 0.01)];
    let mut session = FlSessionBuilder::new(&cfg).quiet().build().unwrap();
    session.step(0).unwrap();
    assert_eq!(session.server().alpha(), 0.05);
    session.step(3).unwrap();
    assert_eq!(session.server().alpha(), 0.01);
}

// ----------------------------------------------------------- dual-side

#[test]
fn dual_side_downlink_converges_and_beats_sgd_baseline() {
    // the acceptance scenario: --downlink "svd(p=0.1)+laq(beta=8)" on the
    // synth workload converges and ships strictly fewer downlink bits
    // than the SGD baseline's full-precision broadcast
    let base = tiny(SchemeConfig::Qrr(PPolicy::Fixed(0.2)), ModelKind::Mlp, DatasetKind::Mnist);
    let sgd_down_bits = {
        let mut cfg = base.clone();
        cfg.scheme = SchemeConfig::Sgd;
        run(&cfg).history.total_down_bits()
    };
    let mut cfg = base;
    cfg.downlink = Some(PipelineSpec::parse("svd(p=0.1)+laq(beta=8)").unwrap());
    let h = run(&cfg).history;
    assert!(
        h.total_down_bits() < sgd_down_bits,
        "dual-side downlink {} not below SGD baseline {}",
        h.total_down_bits(),
        sgd_down_bits
    );
    // far below, in fact: p=0.1 factors + 8-bit codes
    assert!(h.total_down_bits() * 3 < sgd_down_bits);
    let first = h.evals.first().unwrap().loss;
    let last = h.evals.last().unwrap().loss;
    assert!(last < first, "dual-side run did not converge: {first} -> {last}");
    // uplink and downlink are accounted separately
    assert!(h.total_bits() > 0);
    assert_ne!(h.total_bits(), h.total_down_bits());
    for r in &h.rounds {
        assert!(r.ratio < 1.0, "round ratio {} not < 1", r.ratio);
    }
}

#[test]
fn dual_side_matches_uncompressed_downlink_closely_at_high_rank() {
    // a near-lossless downlink (p=1, beta=12) must track the
    // uncompressed broadcast's learning curve
    let base = tiny(SchemeConfig::Sgd, ModelKind::Mlp, DatasetKind::Mnist);
    let plain = run(&base).history;
    let mut cfg = base;
    cfg.downlink = Some(PipelineSpec::parse("svd(p=1.0)+laq(beta=12)").unwrap());
    let dual = run(&cfg).history;
    let a = plain.evals.last().unwrap().loss;
    let b = dual.evals.last().unwrap().loss;
    assert!(
        (a - b).abs() < 0.25 * a.abs().max(0.1),
        "near-lossless downlink diverged: {a} vs {b}"
    );
}
