//! End-to-end validation driver (EXPERIMENTS.md §E2E): the paper's
//! experiment-1 workload — 10-client federated training of the 784-200-10
//! MLP — run for a few hundred iterations on the MNIST-like stream, with
//! the full scheme lineup (SGD, SLAQ, QRR p=0.3/0.1), logging the loss
//! curve and writing every figure series to `results/e2e/`.
//!
//! ```sh
//! cargo run --release --example e2e_mnist            # 300 iterations
//! cargo run --release --example e2e_mnist -- 1000    # paper scale
//! ```

use qrr::fl::metrics::markdown_table;
use qrr::prelude::*;

fn main() -> anyhow::Result<()> {
    qrr::util::logging::init();
    let iters: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    let schemes = [
        SchemeConfig::Sgd,
        SchemeConfig::Slaq,
        SchemeConfig::Qrr(PPolicy::Fixed(0.3)),
        SchemeConfig::Qrr(PPolicy::Fixed(0.1)),
    ];

    let mut rows = Vec::new();
    for scheme in schemes {
        let mut cfg = ExperimentConfig::table1_default(); // 10 clients, β=8, α=0.001, batch 512
        cfg.iters = iters;
        cfg.train_n = 20_000; // synthetic stream size (paper: 60k MNIST)
        cfg.test_n = 4_000;
        cfg.eval_every = (iters / 12).max(1);
        cfg.scheme = scheme;
        println!("\n=== {} ({iters} iterations, 10 clients) ===", scheme.label());
        let t = qrr::util::Timer::start();
        let report = FlSessionBuilder::new(&cfg).build()?.run()?;
        println!("wall time {:.1}s", t.secs());

        // loss curve to stdout (the "few hundred steps, log the loss")
        print!("loss curve:");
        for e in &report.history.evals {
            print!("  {}:{:.3}", e.iter + 1, e.loss);
        }
        println!();
        qrr::experiments::write_run_outputs(
            "results/e2e",
            &format!("e2e_{}", scheme.label().replace(['(', ')', '=', '.'], "_")),
            &report,
        )?;
        rows.push(report.history.table_row());
    }

    println!("\n=== E2E summary (paper Table I shape) ===\n{}", markdown_table(&rows));
    let sgd_bits = rows[0].bits as f64;
    for r in &rows[2..] {
        println!(
            "{}: {:.2}% of SGD bits, accuracy {:+.2}% vs SGD",
            r.algorithm,
            100.0 * r.bits as f64 / sgd_bits,
            100.0 * (r.accuracy - rows[0].accuracy)
        );
    }
    println!("\nseries written to results/e2e/*.csv");
    Ok(())
}
