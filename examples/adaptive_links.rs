//! Network-critical deployment demo (paper experiment 3's motivation):
//! clients sit behind links spanning 100 kbit/s to 10 Mbit/s; QRR's `p`
//! is assigned per client from its link speed, and the simulated
//! round-trip network time is compared against fixed-p and SGD.
//!
//! ```sh
//! cargo run --release --example adaptive_links
//! ```

use qrr::prelude::*;

fn main() -> anyhow::Result<()> {
    qrr::util::logging::init();

    let mut base = ExperimentConfig::table1_default();
    base.clients = 6;
    base.iters = 20;
    base.batch = 32;
    base.train_n = 1_800;
    base.test_n = 400;
    base.eval_every = 10;
    base.lr_schedule = vec![(0, 0.02)];
    base.link_slow_bps = 1e5; // 100 kbit/s sensor uplink
    base.link_fast_bps = 1e7; // 10 Mbit/s

    println!("client links (slowest -> fastest):");
    for (i, link) in LinkModel::spread(base.clients, base.link_slow_bps, base.link_fast_bps)
        .iter()
        .enumerate()
    {
        println!(
            "  client {i}: {:>9.0} bit/s -> p = {:.2}",
            link.bandwidth_bps,
            link.adaptive_p(base.link_slow_bps, base.link_fast_bps, 0.1, 0.3)
        );
    }

    let mut results = Vec::new();
    for scheme in [
        SchemeConfig::Sgd,
        SchemeConfig::Qrr(PPolicy::Fixed(0.3)),
        SchemeConfig::Qrr(PPolicy::Adaptive { lo: 0.1, hi: 0.3 }),
    ] {
        let mut cfg = base.clone();
        cfg.scheme = scheme;
        let report = FlSessionBuilder::new(&cfg).build()?.run()?;
        results.push((scheme.label(), report));
    }

    println!("\n{:<16} {:>12} {:>14} {:>10}", "scheme", "bits", "net time", "accuracy");
    for (label, report) in &results {
        let h = &report.history;
        println!(
            "{:<16} {:>12} {:>12.2} s {:>9.1}%",
            label,
            qrr::util::fmt::bits_sci(h.total_bits()),
            h.total_net_time().as_secs_f64(),
            100.0 * h.evals.last().map(|e| e.accuracy).unwrap_or(0.0),
        );
    }
    let sgd_t = results[0].1.history.total_net_time().as_secs_f64();
    let ada_t = results[2].1.history.total_net_time().as_secs_f64();
    println!(
        "\nadaptive QRR cuts simulated network time {:.1}x vs SGD \
         (the slowest link no longer dominates the synchronous round)",
        sgd_t / ada_t
    );
    Ok(())
}
