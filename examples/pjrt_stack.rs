//! The full three-layer stack on the request path: the FL round loop
//! (L3 Rust) computes every gradient through the AOT-compiled JAX+Pallas
//! artifacts (L2/L1) via PJRT — python is nowhere in the process.
//!
//! Requires `make artifacts` first.
//!
//! ```sh
//! make artifacts
//! cargo run --release --example pjrt_stack
//! ```

use qrr::prelude::*;

fn main() -> anyhow::Result<()> {
    qrr::util::logging::init();

    let manifest = qrr::runtime::Manifest::load(&qrr::runtime::artifacts_dir())
        .map_err(|e| anyhow::anyhow!("{e:#}\nrun `make artifacts` first"))?;
    println!("loaded manifest with {} artifacts", manifest.entries.len());

    let mut cfg = ExperimentConfig::table1_default();
    cfg.backend = Backend::Pjrt; // <- gradients through PJRT/HLO
    cfg.scheme = SchemeConfig::Qrr(PPolicy::Fixed(0.2));
    cfg.clients = 4;
    cfg.iters = 12;
    cfg.batch = 32; // matches the b32 artifacts exactly
    cfg.train_n = 1_600;
    cfg.test_n = 320;
    cfg.eval_every = 4;
    cfg.lr_schedule = vec![(0, 0.02)];

    let t = qrr::util::Timer::start();
    let report = FlSessionBuilder::new(&cfg).build()?.run()?;
    println!(
        "\n12 federated rounds through the PJRT backend in {:.1}s\n{}",
        t.secs(),
        report.markdown_table()
    );

    // sanity: the same config on the native backend reaches a similar loss
    cfg.backend = Backend::Native;
    let native = FlSessionBuilder::new(&cfg).build()?.run()?;
    let lp = report.history.evals.last().unwrap().loss;
    let ln = native.history.evals.last().unwrap().loss;
    println!("final test loss: pjrt {lp:.4} vs native {ln:.4}");
    anyhow::ensure!(
        (lp - ln).abs() / ln.max(1e-6) < 0.15,
        "backends diverged beyond tolerance"
    );
    println!("backends agree — L1/L2 artifacts and the native oracle match end-to-end");
    Ok(())
}
