//! Quickstart: the smallest end-to-end QRR run on the session API.
//!
//! Builds a 5-client federated MNIST-like MLP experiment through
//! [`FlSessionBuilder`], runs 30 iterations with the paper's QRR scheme
//! (p = 0.2, β = 8) and prints the paper-style result row plus the bits
//! saved vs full-precision SGD.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use qrr::prelude::*;

fn main() -> anyhow::Result<()> {
    qrr::util::logging::init();

    // Start from the paper's experiment-1 defaults and shrink for a demo.
    let mut cfg = ExperimentConfig::table1_default();
    cfg.clients = 5;
    cfg.iters = 30;
    cfg.batch = 64;
    cfg.train_n = 2_000;
    cfg.test_n = 500;
    cfg.eval_every = 10;
    cfg.lr_schedule = vec![(0, 0.02)];

    // The paper's scheme: truncated-SVD / Tucker compression + LAQ
    // quantization at p = 0.2.
    cfg.scheme = SchemeConfig::Qrr(PPolicy::Fixed(0.2));
    let qrr_report = FlSessionBuilder::new(&cfg).build()?.run()?;

    // The FedAvg baseline on the identical stream.
    cfg.scheme = SchemeConfig::Sgd;
    let sgd_report = FlSessionBuilder::new(&cfg).build()?.run()?;

    println!("\n== QRR ==\n{}", qrr_report.markdown_table());
    println!("== SGD ==\n{}", sgd_report.markdown_table());

    let q = qrr_report.history.total_bits();
    let s = sgd_report.history.total_bits();
    println!(
        "QRR uploaded {} vs SGD {} — {:.1}% of the bits",
        qrr::util::fmt::bits_sci(q),
        qrr::util::fmt::bits_sci(s),
        100.0 * q as f64 / s as f64
    );

    // The same experiment under a harsher scenario: only 60% of clients
    // are sampled each round and slow links lose uploads — one builder
    // call, no new round loop.
    cfg.scheme = SchemeConfig::Qrr(PPolicy::Fixed(0.2));
    cfg.participation = ParticipationConfig::Dropout { fraction: 0.6, drop_prob: 0.3 };
    let lossy = FlSessionBuilder::new(&cfg).build()?.run()?;
    println!(
        "with 60% sampling + link dropout: {} communications (vs {})",
        lossy.history.total_comms(),
        qrr_report.history.total_comms()
    );

    // Dual-side: compress the broadcast too. The server delta-encodes
    // the model through its own pipeline each round and clients
    // reconstruct locally — no direction ships full precision.
    cfg.participation = ParticipationConfig::Full;
    let dual = FlSessionBuilder::new(&cfg)
        .downlink(PipelineSpec::parse("svd(p=0.1)+laq(beta=8)")?)
        .build()?
        .run()?;
    println!(
        "dual-side downlink: {} vs full-precision broadcast {} ({:.1}% of the bits)",
        qrr::util::fmt::bits_sci(dual.history.total_down_bits()),
        qrr::util::fmt::bits_sci(qrr_report.history.total_down_bits()),
        100.0 * dual.history.total_down_bits() as f64
            / qrr_report.history.total_down_bits() as f64
    );
    Ok(())
}
